"""Compressed egress (repro.core.egress): per-packet wire accounting, the
int8 error bound and topk exactness contracts, bit-exact "none" baseline,
and drop-in use as the consumer stage of run_pipelined and a serve
Session (the two ``consumer(step, partial)`` slots it targets)."""

import numpy as np
import pytest

from repro.core import EGRESS_KINDS, CompressedEgress, EgressPacket
from repro.core.denoise import DenoiseConfig, StreamingDenoiser
from repro.core.streaming import run_pipelined
from repro.data.prism import PrismSource
from repro.serve import Session, SessionScheduler


def _cfg(**kw):
    base = dict(num_groups=4, frames_per_group=20, height=16, width=64)
    base.update(kw)
    return DenoiseConfig(**base)


def _partials(cfg, seed=3):
    """(partials, groups): the per-step running estimates a consumer sees."""
    den = StreamingDenoiser(cfg)
    groups = list(PrismSource(cfg, seed=seed).groups())
    state, outs = den.init(), []
    for k, g in enumerate(groups):
        state = den.ingest(state, np.asarray(g), step=k)
        outs.append(np.asarray(den.filter.partial(state, step_index=k)))
    return outs, groups


# ---------------------------------------------------------------------------
# Construction and validation.
# ---------------------------------------------------------------------------


def test_bad_kind_and_k_fraction_raise():
    with pytest.raises(ValueError, match="egress kind"):
        CompressedEgress("zstd")
    with pytest.raises(ValueError, match="k_fraction"):
        CompressedEgress("topk", k_fraction=0.0)
    with pytest.raises(ValueError, match="k_fraction"):
        CompressedEgress("topk", k_fraction=1.5)
    for kind in EGRESS_KINDS:  # every advertised kind constructs
        CompressedEgress(kind)


# ---------------------------------------------------------------------------
# Per-kind wire contracts on real pipeline partials.
# ---------------------------------------------------------------------------


def test_none_round_trip_bit_exact():
    cfg = _cfg()
    parts, _ = _partials(cfg)
    eg = CompressedEgress("none", center=cfg.offset)
    for k, p in enumerate(parts):
        eg(k, p)
    assert len(eg.packets) == cfg.num_groups
    for k, p in enumerate(parts):
        np.testing.assert_array_equal(
            eg.decompress(k), p.astype(np.float32)
        )
    assert eg.wire_bytes == eg.raw_bytes
    assert eg.reduction == 1.0


def test_int8_error_bounded_by_half_scale():
    cfg = _cfg()
    parts, _ = _partials(cfg)
    eg = CompressedEgress("int8", center=cfg.offset)
    for k, p in enumerate(parts):
        eg(k, p)
    for k, p in enumerate(parts):
        pkt = eg.packets[k]
        got = eg.decompress(k)
        assert got.shape == p.shape
        err = np.abs(got.astype(np.float64) - p.astype(np.float64))
        # + 1e-3: f32 rounding when the ~4096 center is re-added
        assert err.max() <= pkt.scale / 2 + 1e-3
        # one f32 scale rides along with the int8 values
        assert pkt.wire_bytes == p.size + 4
        assert pkt.raw_bytes == p.size * 4
    assert 3.5 < eg.reduction < 4.01  # ~4x minus the per-packet scale


def test_topk_kept_pixels_exact_dropped_decode_to_center():
    cfg = _cfg()
    parts, _ = _partials(cfg)
    frac = 0.1
    eg = CompressedEgress("topk", center=cfg.offset, k_fraction=frac)
    for k, p in enumerate(parts):
        eg(k, p)
    for k, p in enumerate(parts):
        pkt = eg.packets[k]
        vals, idx = pkt.payload
        assert vals.size == max(1, int(p.size * frac))
        assert pkt.wire_bytes == vals.size * 8  # f32 value + i32 index
        got = eg.decompress(k)
        flat_p, flat_g = p.reshape(-1), got.reshape(-1)
        kept = np.zeros(p.size, bool)
        kept[idx] = True
        # kept pixels reconstruct exactly (center - center cancels in f32
        # because partial values sit near the offset: assert exactly)
        np.testing.assert_array_equal(
            flat_g[kept], flat_p[kept].astype(np.float32)
        )
        np.testing.assert_array_equal(
            flat_g[~kept], np.float32(cfg.offset)
        )
    # 4 raw bytes/pixel vs 8 wire bytes per kept pixel: 4/(8*frac)
    assert eg.reduction == pytest.approx(4.0 / (8 * frac), rel=0.05)


def test_packet_raw_bytes_is_f32_frame():
    pkt = EgressPacket(
        step=0, kind="none", shape=(10, 16, 64),
        payload=(np.zeros(10 * 16 * 64, np.float32),),
    )
    assert pkt.raw_bytes == 10 * 16 * 64 * 4


# ---------------------------------------------------------------------------
# Drop-in consumer: run_pipelined and a serve Session.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", EGRESS_KINDS)
def test_run_pipelined_consumer_integration(kind):
    cfg = _cfg()
    parts, groups = _partials(cfg)
    eg = CompressedEgress(kind, center=cfg.offset, k_fraction=0.1)
    out, rep = run_pipelined(cfg, iter(groups), consumer=eg)
    assert rep.drops == 0
    assert [p.step for p in eg.packets] == list(range(cfg.num_groups))
    # the last packet decodes the final estimate: exact for "none",
    # within the int8 bound otherwise; topk keeps the top pixels exact
    final = np.asarray(out).astype(np.float32)
    got = eg.decompress(-1)
    if kind == "none":
        np.testing.assert_array_equal(got, final)
    elif kind == "int8":
        assert np.abs(got - final).max() <= eg.packets[-1].scale / 2 + 1e-3
    else:
        _, idx = eg.packets[-1].payload
        np.testing.assert_array_equal(
            got.reshape(-1)[idx], final.reshape(-1)[idx]
        )
    if kind != "none":
        assert eg.reduction > 3.0


def test_serve_session_consumer_integration():
    cfg = _cfg(backend="xla")
    groups = list(PrismSource(cfg, seed=5).groups())
    eg = CompressedEgress("int8", center=cfg.offset)
    with SessionScheduler(slots_per_executor=1, max_executors=1) as sched:
        handle = sched.submit(
            Session(config=cfg, source=iter(groups), consumer=eg)
        )
        out, rep = handle.result(timeout=300)
    assert len(eg.packets) == cfg.num_groups
    final = np.asarray(out).astype(np.float32)
    assert (
        np.abs(eg.decompress(-1) - final).max()
        <= eg.packets[-1].scale / 2 + 1e-3
    )
