"""Blocked (banded / q-chunked) attention must match the naive oracle.

This is the attention-level instance of the paper's Algorithm-3 idea
(bounded working set, stream in blocks), so we sweep it like a kernel:
shapes × window × GQA grouping against the naive _sdpa reference.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import attention as A


def _cfg(**kw):
    base = dict(
        name="t", family="dense", num_layers=1, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64, dtype="float32",
    )
    base.update(kw)
    return ArchConfig(**base)


def _qkv(b, s, h, kv, d, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), jnp.float32)
    return q, k, v


def _naive(q, k, v, window, cfg):
    s = q.shape[1]
    pos = jnp.arange(s)
    mask = A._causal_window_mask(pos, pos, window)[None]
    return A._sdpa(q, k, v, mask[:, None], cfg)


@pytest.mark.parametrize("s", [16, 48, 64, 100])
@pytest.mark.parametrize("window", [8, 16, 24])
def test_banded_matches_naive(s, window):
    cfg = _cfg()
    q, k, v = _qkv(2, s, 4, 2, 16)
    ref = _naive(q, k, v, window, cfg)
    out = A._banded_sdpa(q, k, v, window, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("s", [16, 64, 100])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("q_chunk", [8, 32, 128])
def test_qchunk_matches_naive(s, window, q_chunk):
    cfg = _cfg()
    q, k, v = _qkv(2, s, 4, 2, 16, seed=3)
    ref = _naive(q, k, v, window, cfg)
    out = A._qchunk_sdpa(q, k, v, window, cfg, q_chunk=q_chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("kv_heads", [1, 2, 4])
def test_gqa_grouping(kv_heads):
    cfg = _cfg(num_kv_heads=kv_heads)
    q, k, v = _qkv(1, 64, 4, kv_heads, 16, seed=5)
    ref = _naive(q, k, v, 16, cfg)
    out = A._banded_sdpa(q, k, v, 16, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    out2 = A._qchunk_sdpa(q, k, v, 16, cfg, q_chunk=16)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref), atol=2e-5)


def test_full_model_blocked_vs_naive():
    """End-to-end: whole model forward equal under both implementations."""
    from repro.models import build_model
    from repro.launch.inputs import make_train_batch

    # force blocked path by lowering the threshold via long seq
    cfg_b = _cfg(num_layers=2, sliding_window=16)
    cfg_n = dataclasses.replace(cfg_b, attention_impl="naive")
    mb = build_model(cfg_b)
    mn = build_model(cfg_n)
    params = mb.init(jax.random.PRNGKey(0))
    batch = make_train_batch(cfg_b, 1, 2048 + 32)  # crosses _BLOCKED_MIN_SEQ
    lb = mb.forward(params, batch)
    ln = mn.forward(params, batch)
    np.testing.assert_allclose(
        np.asarray(lb), np.asarray(ln), atol=3e-4, rtol=1e-3
    )


def test_soft_cap_applies_in_blocked_paths():
    cfg = _cfg(logit_soft_cap=5.0)
    q, k, v = _qkv(1, 64, 4, 2, 16, seed=9)
    ref = _naive(q, k, v, 16, cfg)
    out = A._banded_sdpa(q, k, v, 16, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
