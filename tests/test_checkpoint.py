"""Checkpointing: atomic roundtrip, keep-N rotation, async writer,
mesh-agnostic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_tree, save_tree


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "opt": {"mu": jnp.ones((8, 16)), "step": jnp.asarray(7, jnp.int32)},
    }


def _assert_tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a, b,
    )


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    save_tree(str(tmp_path / "ck"), state, step=42)
    restored, step = restore_tree(str(tmp_path / "ck"))
    assert step == 42
    _assert_tree_equal(state, restored)


def test_atomic_no_partial_dirs(tmp_path):
    state = _state()
    save_tree(str(tmp_path / "ck"), state, step=1)
    leftovers = [p for p in os.listdir(tmp_path) if p.startswith(".tmp")]
    assert leftovers == []


def test_manager_keep_policy(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, _state(s), blocking=True)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_manager_async_overlap(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = _state()
    mgr.save(1, state)           # async
    # mutate the original AFTER save snapshotted it
    state["params"]["w"] = state["params"]["w"] * 0.0
    mgr.wait()
    restored, step = mgr.restore(1)
    assert step == 1
    assert np.abs(np.asarray(restored["params"]["w"])).max() > 0  # snapshot taken


def test_restore_with_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, _state(), blocking=True)
    from repro.jax_compat import make_mesh as _make_mesh
    mesh = _make_mesh((1,), ("data",))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), _state()
    )
    restored, _ = mgr.restore(shardings=sh)
    assert restored["params"]["w"].sharding.mesh.shape["data"] == 1


def test_restore_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree, step = mgr.restore()
    assert tree is None and step is None
