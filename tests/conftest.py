"""Shared fixtures: the deterministic fault-injection harness.

The fleet tests script executor crashes/stalls/slow-steps against cohort
step indices (never wall-clock) and drive supervision time through an
injectable clock. These fixtures hand every test a fresh fault plan and
clock, plus a ``FleetScheduler`` factory that guarantees teardown: any
scheduler a test builds is aborted (and its scripted stalls poisoned
free) even when the test body raises, so a failing assertion can never
leave a stalled executor thread holding the session.
"""

import pytest

from repro.serve import FakeClock, FaultPlan, FleetScheduler


@pytest.fixture
def fake_clock():
    """Virtual time: only ``advance()`` moves it."""
    return FakeClock()


@pytest.fixture
def fault_plan():
    """Empty fault script; tests chain ``.crash/.stall/.slow`` onto it."""
    return FaultPlan()


@pytest.fixture
def fleet_factory(tmp_path):
    """Build ``FleetScheduler``\\ s wired to a per-test checkpoint
    directory (pass ``checkpoint_dir=None`` to opt out); everything built
    here is torn down unconditionally."""
    created = []

    def make(**kwargs):
        kwargs.setdefault("checkpoint_dir", str(tmp_path / "ckpt"))
        fleet = FleetScheduler(**kwargs)
        created.append(fleet)
        return fleet

    yield make
    for fleet in created:
        if fleet.faults is not None:
            # free any stall a failing test left held, and make sure the
            # released thread terminates instead of folding anything
            for ex in list(fleet._executors):
                fleet.faults.poison(ex.name)
        try:
            fleet.shutdown(wait=False)
        except Exception:
            pass
