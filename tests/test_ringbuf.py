"""RingBuffer contract: FIFO exactly-once under backpressure, capacity-1
degenerate ring, drop-oldest accounting, close semantics, timing stats."""

import threading
import time

import pytest

from repro.core.ringbuf import POLICIES, RingBuffer, RingClosed


def test_fifo_exactly_once_single_thread():
    ring = RingBuffer(4)
    for i in range(4):
        ring.put(i)
    assert len(ring) == 4
    assert [ring.get() for _ in range(4)] == [0, 1, 2, 3]
    assert len(ring) == 0
    assert ring.stats.puts == ring.stats.gets == 4
    assert ring.stats.drops == 0
    # nothing ever blocked: the wait timers must be exactly zero, so
    # "put_wait_s > 0" elsewhere really proves backpressure engaged
    assert ring.stats.put_wait_s == 0.0
    assert ring.stats.get_wait_s == 0.0


def test_capacity_one_ring():
    """num_slots=1: the fully serialized ring still moves every item."""
    ring = RingBuffer(1)
    got = []

    def consume():
        for item in ring:
            got.append(item)
            time.sleep(0.001)  # keep the slot occupied: force backpressure

    t = threading.Thread(target=consume)
    t.start()
    for i in range(50):
        ring.put(i, timeout=10.0)
    ring.close()
    t.join(timeout=10.0)
    assert got == list(range(50))
    assert ring.stats.occupancy_max == 1
    assert ring.stats.put_wait_s > 0.0  # the producer did block on full


def test_producer_faster_than_consumer_no_loss():
    """Backpressure engages (producer blocks) and no frame is ever lost."""
    ring = RingBuffer(3)  # producer outruns this immediately
    n = 40
    got = []

    def produce():
        for i in range(n):
            ring.put(i)
        ring.close()

    t = threading.Thread(target=produce)
    t.start()
    for item in ring:
        got.append(item)
        time.sleep(0.002)  # consumer is the slow stage
    t.join(timeout=10.0)
    assert got == list(range(n))  # exactly-once, in order
    assert ring.stats.drops == 0
    assert ring.stats.put_wait_s > 0.0  # backpressure actually engaged
    assert ring.stats.occupancy_max <= 3
    # the ring ran full: mean depth near capacity while producer waited
    assert ring.stats.occupancy_mean > 2.0


def test_drop_oldest_accounting():
    ring = RingBuffer(3, policy="drop_oldest")
    for i in range(10):
        ring.put(i)  # never blocks
    # the 3 slots hold the newest window; 7 oldest items were discarded
    assert ring.stats.drops == 7
    assert ring.stats.puts == 10
    assert [ring.get() for _ in range(3)] == [7, 8, 9]
    ring.close()
    with pytest.raises(RingClosed):
        ring.get()


def test_drop_oldest_interleaved_window():
    ring = RingBuffer(2, policy="drop_oldest")
    ring.put(0)
    ring.put(1)
    assert ring.get() == 0
    ring.put(2)
    ring.put(3)  # full again: drops 1
    assert ring.stats.drops == 1
    assert [ring.get(), ring.get()] == [2, 3]


def test_put_after_close_never_evicts_buffered_items():
    """A put racing close() on a full drop_oldest ring must raise, not
    shed a chunk the consumer was promised it could drain."""
    ring = RingBuffer(1, policy="drop_oldest")
    ring.put("staged")
    ring.close()
    with pytest.raises(RingClosed):
        ring.put("late")
    assert ring.stats.drops == 0
    assert ring.get() == "staged"  # still drainable after close


def test_close_semantics():
    ring = RingBuffer(4)
    ring.put("a")
    ring.put("b")
    ring.close()
    ring.close()  # idempotent
    # buffered items drain after close ...
    assert ring.get() == "a"
    assert ring.get() == "b"
    # ... then the ring reports end-of-stream
    with pytest.raises(RingClosed):
        ring.get()
    with pytest.raises(RingClosed):
        ring.put("c")


def test_close_wakes_blocked_consumer():
    ring = RingBuffer(2)
    woke = []

    def consume():
        try:
            ring.get()
        except RingClosed:
            woke.append(True)

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.01)  # let it block on the empty ring
    ring.close()
    t.join(timeout=10.0)
    assert woke == [True]
    assert ring.stats.get_wait_s > 0.0


def test_timeouts():
    ring = RingBuffer(1)
    with pytest.raises(TimeoutError):
        ring.get(timeout=0.01)
    ring.put("x")
    with pytest.raises(TimeoutError):
        ring.put("y", timeout=0.01)


def test_dwell_timing():
    ring = RingBuffer(2)
    ring.put(1)
    time.sleep(0.01)
    ring.get()
    assert ring.stats.dwell_s >= 0.009
    assert ring.stats.dwell_mean_s == pytest.approx(ring.stats.dwell_s)


def test_validation():
    with pytest.raises(ValueError, match="num_slots"):
        RingBuffer(0)
    with pytest.raises(ValueError, match="policy"):
        RingBuffer(2, policy="spill")
    assert POLICIES == ("block", "drop_oldest")


# -- percentile / telemetry edge cases ---------------------------------------


def test_dwell_percentile_empty_buffer_is_zero():
    ring = RingBuffer(2)
    for q in (0.0, 50.0, 100.0):
        assert ring.stats.dwell_percentile_s(q) == 0.0


def test_dwell_percentile_single_sample_is_every_percentile():
    ring = RingBuffer(2)
    ring.put("x")
    ring.get()
    sample = ring.stats.dwell_samples[0]
    for q in (0.0, 1.0, 50.0, 99.0, 100.0):
        assert ring.stats.dwell_percentile_s(q) == sample


def test_dwell_percentile_rejects_out_of_range_q():
    ring = RingBuffer(2)
    ring.put("x")
    ring.get()
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        ring.stats.dwell_percentile_s(-1.0)
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        ring.stats.dwell_percentile_s(100.001)


def test_dwell_percentile_ignores_injected_non_finite_samples():
    ring = RingBuffer(2)
    ring.put("x")
    ring.get()
    ring.stats.dwell_samples.append(float("nan"))
    ring.stats.dwell_samples.append(float("inf"))
    assert ring.stats.dwell_percentile_s(100.0) == max(
        s for s in ring.stats.dwell_samples if s == s and s != float("inf")
    )


def test_last_dwell_tracks_most_recent_get():
    ring = RingBuffer(2)
    assert ring.stats.last_dwell_s == 0.0
    ring.put(1)
    time.sleep(0.01)
    ring.get()
    first = ring.stats.last_dwell_s
    assert first >= 0.009
    ring.put(2)
    ring.get()
    assert ring.stats.last_dwell_s <= first


def test_ring_name_attribution():
    assert RingBuffer(1).name == ""
    assert RingBuffer(1, name="stage").name == "stage"
