"""Error-feedback gradient compression: wire savings + convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import compress as C


def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
    q, scale = C.int8_compress(x)
    back = C.int8_decompress(q, scale)
    assert q.dtype == jnp.int8
    # quantization error bounded by half a step
    assert float(jnp.abs(back - x).max()) <= float(scale) * 0.5 + 1e-6


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    vals, idx = C.topk_compress(x, 2)
    back = C.topk_decompress(vals, idx, x.shape)
    np.testing.assert_allclose(
        np.asarray(back), np.asarray([0.0, -5.0, 0.0, 3.0, 0.0]), atol=1e-7
    )


def test_wire_bytes_accounting():
    grads = {"w": jnp.zeros((1000,)), "b": jnp.zeros((100,))}
    exact = C.wire_bytes(grads, kind="none")
    int8 = C.wire_bytes(grads, kind="int8")
    topk = C.wire_bytes(grads, kind="topk", k_fraction=0.05)
    assert exact == 4400
    assert int8 < exact / 3.5
    assert topk < exact / 2


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_error_feedback_converges_least_squares(kind):
    """SGD on a quadratic with compressed grads + EF reaches the optimum;
    WITHOUT error feedback, top-k at small k stalls measurably earlier."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    x_star, *_ = np.linalg.lstsq(np.asarray(A), np.asarray(b), rcond=None)

    def grad(x):
        return {"x": A.T @ (A @ x["x"] - b) / A.shape[0]}

    x = {"x": jnp.zeros((16,))}
    res = C.ef_init(x)
    lr = 0.05
    for _ in range(800):
        g = grad(x)
        sent, res = C.ef_step(g, res, kind=kind, k_fraction=0.25)
        x = jax.tree_util.tree_map(lambda p, s: p - lr * s, x, sent)
    err = float(jnp.linalg.norm(x["x"] - jnp.asarray(x_star)))
    assert err < 5e-2, err


def test_ef_residual_carries_dropped_mass():
    g = {"w": jnp.asarray([1.0, 0.001, -2.0, 0.002])}
    res = C.ef_init(g)
    sent, res = C.ef_step(g, res, kind="topk", k_fraction=0.5)
    # the two small entries live in the residual now
    assert float(jnp.abs(res["w"][1] - 0.001)) < 1e-6
    assert float(jnp.abs(res["w"][3] - 0.002)) < 1e-6
    # and are sent once they accumulate
    sent2, res2 = C.ef_step(
        {"w": jnp.zeros(4)}, res, kind="topk", k_fraction=0.5
    )
    assert float(jnp.abs(sent2["w"]).sum()) > 0


def test_topk_decompress_jit_compatible_nd_shape():
    """The scatter target is sized from static python shape metadata, so
    decompress works under jit for any rank (the egress path jits it)."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 4, 5)), jnp.float32)
    vals, idx = C.topk_compress(x.reshape(-1), 7)
    jitted = jax.jit(C.topk_decompress, static_argnums=2)
    dense = jitted(vals, idx, (3 * 4 * 5,)).reshape(3, 4, 5)
    eager = C.topk_decompress(vals, idx, (3 * 4 * 5,)).reshape(3, 4, 5)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(eager))
    # kept entries match the source exactly; everything else is zero
    np.testing.assert_array_equal(
        np.asarray(dense).reshape(-1)[np.asarray(idx)],
        np.asarray(x).reshape(-1)[np.asarray(idx)],
    )
    assert np.count_nonzero(np.asarray(dense)) <= 7
