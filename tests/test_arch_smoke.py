"""Per-architecture smoke tests (reduced same-family configs, CPU).

One forward/train step per arch: output shapes + finite values, plus a
real optimizer step to check the full train path end-to-end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.inputs import make_decode_batch, make_train_batch
from repro.distributed import sharding as sh
from repro.models import build_model

B, S = 2, 16


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        m = build_model(cfg)
        out[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, built):
    cfg, m, params = built[arch]
    batch = make_train_batch(cfg, B, S)
    logits = jax.jit(m.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss_signal(arch, built):
    """One SGD step on the smoke config must produce a finite, changed loss."""
    cfg, m, params = built[arch]
    batch = make_train_batch(cfg, B, S)

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(m.loss)(p, b)
        new = jax.tree_util.tree_map(lambda w, g: w - 1e-2 * g, p, grads)
        return loss, new

    loss0, params1 = step(params, batch)
    loss1, _ = step(params1, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    assert float(loss1) != float(loss0)
    assert float(loss1) < float(loss0) + 0.5  # no explosion


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch, built):
    cfg, m, params = built[arch]
    caches = sh.init_params(jax.random.PRNGKey(1), m.cache_spec(B, S))
    if cfg.family == "audio":
        from repro.models import encdec as ED

        frames = make_train_batch(cfg, B, S)["frames"]
        enc = ED.encode(params, frames, cfg)
        caches["cross"] = ED.precompute_cross_kv(params, enc, cfg)
    db = make_decode_batch(cfg, B)
    logits, new_caches = m.decode_step(params, caches, db, jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure must be preserved (scan/carry invariant)
    assert jax.tree_util.tree_structure(new_caches) == jax.tree_util.tree_structure(
        caches
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_positive(arch, built):
    cfg, m, _ = built[arch]
    assert m.param_count() > 0
    assert 0 < m.active_param_count() <= m.param_count()


def test_full_configs_match_assignment():
    """The exact published hyperparameters from the assignment block."""
    expect = {
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "mamba2-780m": (48, 1536, 48, 0, 0, 50280),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    }
    for arch, (nl, dm, h, kv, dff, v) in expect.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (nl, dm, h, kv, dff, v), arch
    # moe specifics
    ds = get_config("deepseek-v2-lite-16b")
    assert (ds.num_experts, ds.num_experts_per_tok, ds.num_shared_experts,
            ds.moe_d_ff, ds.kv_lora_rank) == (64, 6, 2, 1408, 512)
    mx = get_config("mixtral-8x7b")
    assert (mx.num_experts, mx.num_experts_per_tok) == (8, 2)
    mb = get_config("mamba2-780m")
    assert mb.ssm_state_dim == 128


def test_shapes_table():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["prefill_32k"].tokens == 32768 * 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
