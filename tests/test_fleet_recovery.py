"""Fleet fault tolerance under the deterministic fault-injection harness.

Every scenario here is scripted: crashes/stalls/slow-steps fire at cohort
step indices (``FaultPlan``), supervision time is a ``FakeClock`` the test
advances, and every wait is a *bounded event wait* — there are no
wall-clock sleeps anywhere in this file. Covered: kill-executor recovery
(bit-identical resume for every filter), migrate-under-load, straggler
eviction, heartbeat-dead eviction of a stalled executor, double faults
against the restart budget, sparse-checkpoint replay, and the
abort-vs-held-fold drain regression."""

import threading

import numpy as np
import pytest

from repro.core.denoise import DenoiseConfig, StreamingDenoiser
from repro.data.prism import PrismSource
from repro.denoise import FILTERS
from repro.serve import (
    FaultPlan,
    InjectedExecutorFailure,
    Session,
    SessionHandle,
)
from repro.serve.scheduler import _Active

ALL_FILTERS = sorted(FILTERS)
WAIT = 300  # generous bounded waits: first step pays jit compile


def _cfg(**kw):
    base = dict(
        num_groups=6,
        frames_per_group=20,
        height=16,
        width=64,
        backend="xla",
        median_window=3,
    )
    base.update(kw)
    return DenoiseConfig(**base)


def _groups(cfg, seed=3):
    return list(PrismSource(cfg, seed=seed).groups())


def _serial(cfg, groups, steps=None):
    """Oracle: the direct filter calls on the same chunk sequence."""
    den = StreamingDenoiser(cfg)
    state = den.init()
    for k, g in enumerate(groups):
        state = den.ingest(state, np.asarray(g), step=k)
    return np.asarray(den.finalize(state, steps=steps))


def _assert_recovered_output(name, out, ref):
    """Recovery is bit-identical for the exact filters; ema_variance's
    running mean/variance recurrence is still exact under checkpoint +
    replay (same ops, same order, same dtypes), so it gets the same
    assertion — any future divergence should fail loudly here."""
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# Kill-executor recovery: crash mid-stream, resume bit-identically.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_FILTERS)
def test_kill_executor_recovery_bit_identical(name, fleet_factory):
    cfg = _cfg(filter_name=name)
    groups = _groups(cfg)
    plan = FaultPlan().crash("ex0", at_step=3)
    fleet = fleet_factory(slots_per_executor=1, max_executors=2, faults=plan)
    with fleet:
        h = fleet.submit(Session(config=cfg, source=iter(groups), name="k0"))
        out, rep = h.result(timeout=WAIT)
    assert plan.crashed("ex0")
    _assert_recovered_output(name, out, _serial(cfg, groups))
    assert rep.groups == cfg.num_groups
    assert rep.frames == cfg.num_groups * cfg.frames_per_group
    assert rep.restarts == 1
    assert rep.checkpoints >= 1
    assert any(e.startswith("recover@k0->ex1") for e in fleet.events)
    assert fleet.recovery_latencies_s(), "no kill-to-recovered mark recorded"


def test_kill_executor_recovers_all_cotenants(fleet_factory):
    """Both sessions sharing the crashed executor resume exactly."""
    cfg = _cfg()
    ga, gb = _groups(cfg, seed=1), _groups(cfg, seed=2)
    plan = FaultPlan().crash("ex0", at_step=4)
    fleet = fleet_factory(slots_per_executor=2, max_executors=2, faults=plan)
    with fleet:
        ha = fleet.submit(Session(config=cfg, source=iter(ga), name="A"))
        hb = fleet.submit(Session(config=cfg, source=iter(gb), name="B"))
        oa, ra = ha.result(timeout=WAIT)
        ob, rb = hb.result(timeout=WAIT)
    np.testing.assert_array_equal(np.asarray(oa), _serial(cfg, ga))
    np.testing.assert_array_equal(np.asarray(ob), _serial(cfg, gb))
    assert ra.restarts == 1 and rb.restarts == 1


def test_crash_before_first_fold_recovers_fresh(fleet_factory):
    """A session that never folded anything resumes from a fresh init —
    no checkpoint, no replay, still exactly the reference output."""
    cfg = _cfg()
    groups = _groups(cfg)
    plan = FaultPlan().crash("ex0", at_step=0)
    fleet = fleet_factory(slots_per_executor=1, max_executors=2, faults=plan)
    with fleet:
        h = fleet.submit(Session(config=cfg, source=iter(groups), name="f0"))
        out, rep = h.result(timeout=WAIT)
    np.testing.assert_array_equal(np.asarray(out), _serial(cfg, groups))
    assert rep.restarts == 1 and rep.groups == cfg.num_groups


@pytest.mark.parametrize("name", ["temporal_median", "ema_variance"])
def test_recovery_replays_past_sparse_checkpoint(name, fleet_factory):
    """``checkpoint_every=3``: the crash lands two folds past the newest
    snapshot, so recovery must restore @3 and re-fold the replay log."""
    cfg = _cfg(filter_name=name, num_groups=7)
    groups = _groups(cfg)
    plan = FaultPlan().crash("ex0", at_step=5)
    fleet = fleet_factory(
        slots_per_executor=1, max_executors=2, faults=plan, checkpoint_every=3
    )
    with fleet:
        h = fleet.submit(Session(config=cfg, source=iter(groups), name="R"))
        out, rep = h.result(timeout=WAIT)
    _assert_recovered_output(name, out, _serial(cfg, groups))
    assert rep.restarts == 1
    # folded 0..4 before the crash, newest snapshot at steps=3: exactly
    # the two post-snapshot chunks ride the replay log
    assert any("recover@R->" in e and "steps=3+2" in e for e in fleet.events)


# ---------------------------------------------------------------------------
# Live migration at a group boundary, mid-stream, with staged load.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["pair_average", "temporal_median"])
def test_migrate_under_load_bit_identical(name, fleet_factory):
    cfg = _cfg(filter_name=name)
    groups = _groups(cfg)
    gb = _groups(cfg, seed=11)
    gate = threading.Event()
    fed = threading.Event()

    def src():
        yield groups[0]
        yield groups[1]
        fed.set()
        gate.wait(WAIT)
        yield from groups[2:]

    fleet = fleet_factory(slots_per_executor=2, max_executors=2)
    with fleet:
        h = fleet.submit(Session(config=cfg, source=src(), name="m0"))
        hb = fleet.submit(Session(config=cfg, source=iter(gb), name="m1"))
        assert fed.wait(WAIT), "source never staged its pre-gate chunks"
        target = fleet.migrate(h, timeout=WAIT)
        assert target == "ex1"
        gate.set()
        out, rep = h.result(timeout=WAIT)
        ob, rb = hb.result(timeout=WAIT)
    np.testing.assert_array_equal(np.asarray(out), _serial(cfg, groups))
    np.testing.assert_array_equal(np.asarray(ob), _serial(cfg, gb))
    assert rep.migrations == 1 and rep.restarts == 0
    assert rb.migrations == 0  # the co-tenant never noticed
    assert any(e.startswith("migrate@m0:ex0->ex1") for e in fleet.events)


def test_migrate_finished_session_returns_none(fleet_factory):
    cfg = _cfg()
    groups = _groups(cfg)
    fleet = fleet_factory(slots_per_executor=1, max_executors=2)
    with fleet:
        h = fleet.submit(Session(config=cfg, source=iter(groups)))
        out, _ = h.result(timeout=WAIT)
        assert fleet.migrate(h, timeout=WAIT) is None
    np.testing.assert_array_equal(np.asarray(out), _serial(cfg, groups))


# ---------------------------------------------------------------------------
# Supervision: straggler eviction and heartbeat death, virtual time only.
# ---------------------------------------------------------------------------


def _counting_consumer(event, at):
    """Set ``event`` once fold index ``at`` has completed (the consumer
    hook runs on the executor thread after each fold)."""

    def consumer(step, _partial):
        if step >= at:
            event.set()

    return consumer


def test_straggler_evicted_and_session_recovers(fleet_factory, fake_clock):
    """Two 1-slot executors with scripted *virtual* step durations: the
    5x-slower one is flagged against the fleet median and evicted; its
    session resumes elsewhere and the output is untouched."""
    cfg = _cfg()
    ga, gb = _groups(cfg, seed=1), _groups(cfg, seed=2)
    plan = (
        FaultPlan()
        .slow("ex0", extra_s=0.1, from_step=0)
        .slow("ex1", extra_s=0.5, from_step=0)
    )
    fleet = fleet_factory(
        slots_per_executor=1,
        max_executors=3,
        faults=plan,
        clock=fake_clock,
        straggler_threshold=1.5,
        straggler_warmup=3,
    )
    gate_a, gate_b = threading.Event(), threading.Event()
    warm_a, warm_b = threading.Event(), threading.Event()

    def gated(groups, gate):
        def src():
            yield from groups[:4]
            gate.wait(WAIT)
            yield from groups[4:]

        return src()

    with fleet:
        ha = fleet.submit(
            Session(
                config=cfg,
                source=gated(ga, gate_a),
                name="A",
                consumer=_counting_consumer(warm_a, 3),
            )
        )
        hb = fleet.submit(
            Session(
                config=cfg,
                source=gated(gb, gate_b),
                name="B",
                consumer=_counting_consumer(warm_b, 3),
            )
        )
        # fold index 3 completing guarantees folds 0..2 fully recorded
        # their EWMA samples — past warmup on both executors
        assert warm_a.wait(WAIT) and warm_b.wait(WAIT)
        res = fleet.check_faults(probe=False)
        assert res["dead"] == []
        assert res["stragglers"] == ["ex1"]
        assert res["evicted"] == ["ex1"]
        assert res["recovered"] == ["B"]
        gate_a.set()
        gate_b.set()
        oa, ra = ha.result(timeout=WAIT)
        ob, rb = hb.result(timeout=WAIT)
    np.testing.assert_array_equal(np.asarray(oa), _serial(cfg, ga))
    np.testing.assert_array_equal(np.asarray(ob), _serial(cfg, gb))
    assert ra.restarts == 0 and rb.restarts == 1
    assert any(e == "evict@ex1:straggler" for e in fleet.events)


def test_stalled_executor_evicted_by_heartbeat(fleet_factory, fake_clock):
    """A stalled executor stops beating; advancing the fake clock past
    the heartbeat timeout gets it evicted and its session recovered —
    zero real seconds of waiting on silence."""
    cfg = _cfg()
    groups = _groups(cfg)
    plan = FaultPlan().stall("ex0", at_step=2)
    fleet = fleet_factory(
        slots_per_executor=1,
        max_executors=2,
        faults=plan,
        clock=fake_clock,
        heartbeat_timeout_s=60.0,
    )
    with fleet:
        h = fleet.submit(Session(config=cfg, source=iter(groups), name="S"))
        assert plan.wait_stalled("ex0", timeout=WAIT)
        fake_clock.advance(61.0)
        res = fleet.check_faults(probe=False)
        assert res["dead"] == ["ex0"]
        assert res["evicted"] == ["ex0"]
        assert res["recovered"] == ["S"]
        out, rep = h.result(timeout=WAIT)
    np.testing.assert_array_equal(np.asarray(out), _serial(cfg, groups))
    assert rep.restarts == 1 and rep.groups == cfg.num_groups
    assert any(e == "evict@ex0:heartbeat" for e in fleet.events)
    # the zombie thread raised on release instead of folding anything
    ex0 = fleet._executors[0]
    ex0.thread.join(WAIT)
    assert not ex0.thread.is_alive()


# ---------------------------------------------------------------------------
# Double faults vs the restart budget.
# ---------------------------------------------------------------------------


def test_double_fault_recovers_within_budget(fleet_factory):
    cfg = _cfg(num_groups=8)
    groups = _groups(cfg)
    plan = FaultPlan().crash("ex0", at_step=2).crash("ex1", at_step=2)
    fleet = fleet_factory(
        slots_per_executor=1, max_executors=3, faults=plan,
        max_session_restarts=2,
    )
    with fleet:
        h = fleet.submit(Session(config=cfg, source=iter(groups), name="D"))
        out, rep = h.result(timeout=WAIT)
    np.testing.assert_array_equal(np.asarray(out), _serial(cfg, groups))
    assert rep.restarts == 2
    assert sum(e.startswith("recover@D->") for e in fleet.events) == 2


def test_double_fault_exhausts_restart_budget(fleet_factory):
    cfg = _cfg(num_groups=8)
    groups = _groups(cfg)
    plan = FaultPlan().crash("ex0", at_step=2).crash("ex1", at_step=2)
    fleet = fleet_factory(
        slots_per_executor=1, max_executors=3, faults=plan,
        max_session_restarts=1,
    )
    with fleet:
        h = fleet.submit(Session(config=cfg, source=iter(groups), name="D"))
        with pytest.raises(InjectedExecutorFailure):
            h.result(timeout=WAIT)
    assert any(e.startswith("give-up@D") for e in fleet.events)


# ---------------------------------------------------------------------------
# Regression: abort racing a held fold must drain queued sessions.
# ---------------------------------------------------------------------------


def test_abort_with_held_fold_drains_queued_sessions(fleet_factory):
    """``stop(abort=True)`` while the executor thread is held inside a
    fold must still terminally fail both the seated and the *queued*
    session — the queued ``_Active`` used to be left unnotified, hanging
    its ``result()`` forever. Also pins the enqueue-after-death refusal."""
    cfg = _cfg(num_groups=4)
    ga, gb = _groups(cfg, seed=1), _groups(cfg, seed=2)
    plan = FaultPlan().stall("ex0", at_step=1)
    fleet = fleet_factory(
        slots_per_executor=1, max_executors=1, faults=plan,
        max_session_restarts=0,
    )
    ha = fleet.submit(Session(config=cfg, source=iter(ga), name="A"))
    hb = fleet.submit(Session(config=cfg, source=iter(gb), name="B"))
    assert plan.wait_stalled("ex0", timeout=WAIT)
    ex0 = fleet._executors[0]
    ex0.stop(abort=True)  # abort lands while the fold is still held
    plan.poison("ex0")    # release the thread: it must raise, not fold
    ex0.thread.join(WAIT)
    assert not ex0.thread.is_alive()
    with pytest.raises(RuntimeError):
        ha.result(timeout=WAIT)
    with pytest.raises(RuntimeError):
        hb.result(timeout=WAIT)
    # a dead executor refuses new sessions instead of parking them
    spare = _Active(
        SessionHandle(Session(config=cfg, source=iter(gb))),
        99,
        notify_hook=lambda: None,
    )
    assert ex0.enqueue(spare) is False
    fleet.shutdown(wait=False)
