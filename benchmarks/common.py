"""Shared benchmark helpers. Every table prints ``name,us_per_call,derived``
CSV rows via ``emit`` so ``benchmarks.run`` output is machine-readable;
executor tables additionally print full ``StreamReport`` rows via
``emit_report`` (transfer/stall/overlap and the per-stage ring fields —
the data PR 1's CSVs silently dropped).

``bench_record`` additionally appends structured trajectory points to
``BENCH_denoise.json`` (repo root; override with ``BENCH_DENOISE_PATH``) so
speedups of the fused/prefetched paths are tracked across PRs — see
docs/BENCHMARKS.md for the schema.
"""

from __future__ import annotations

import json
import os
import pathlib
import tempfile
import time

import jax
import numpy as np

from repro.core.denoise import DenoiseConfig
from repro.core.streaming import StreamReport

__all__ = [
    "emit",
    "emit_report",
    "timeit",
    "bench_config",
    "bench_record",
    "stream_pass_s",
    "PAPER_G",
    "PAPER_N",
    "PAPER_H",
    "PAPER_W",
]

PAPER_G, PAPER_N = 8, 1000  # paper §6 defaults
PAPER_H, PAPER_W = 80, 256  # one camera bank

_BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_denoise.json"

#: one-shot migration map: 59 legacy points predate the required ``kind``
#: field; their trajectory ``name`` determines the point shape exactly,
#: so ``_migrate_kinds`` backfills from it (unknown names fall back to
#: ``kind == name`` — present, greppable, and honest about provenance).
_KIND_FROM_NAME = {
    "denoise_fused_vs_reference": "speedup",
    "multibank_fused_vs_reference": "speedup",
    "streaming_prefetch_vs_presync": "speedup",
    "inline_prefetch_vs_sync": "speedup",
    "ring_depth_overlap": "speedup",
    "filter_zoo_median_vs_mean_impulse": "snr_gain",
    "multitenant": "multitenant",
    "snr": "snr",
}


def _migrate_kinds(records: list) -> bool:
    """Backfill ``kind`` on legacy points in place; True if anything changed."""
    changed = False
    for rec in records:
        if isinstance(rec, dict) and "kind" not in rec:
            name = rec.get("name")
            # a nameless/mistyped (even unhashable) record still gets a
            # typed string kind — readers can rely on kind being a str
            if isinstance(name, str) and name:
                rec["kind"] = _KIND_FROM_NAME.get(name, name)
            else:
                rec["kind"] = "unknown"
            changed = True
    return changed


def bench_record(name: str, kind: str, **fields) -> None:
    """Append one trajectory point to BENCH_denoise.json.

    Each point is ``{"name", "kind", "timestamp", **fields}``. ``name``
    is the trajectory (the stable identifier readers plot across PRs);
    ``kind`` is the required point shape discriminator (``"speedup"``,
    ``"throughput"``, ``"snr"``, ...) — see docs/BENCHMARKS.md. Loading a
    file that still contains pre-``kind`` legacy points triggers a
    one-shot in-file migration backfilling them from their ``name``.
    The file is a flat JSON list, append-only across runs.

    The append is crash- and concurrency-safe: the new list is written to
    a temp file in the same directory and ``os.replace``\\ d over the
    target (atomic on POSIX), so a benchmark process dying mid-write — or
    two overlapping benchmark runs — can never leave a truncated/corrupt
    file. Concurrent writers may still lose each other's *latest* point
    (last replace wins; there is deliberately no cross-process lock), but
    every reader always sees valid JSON.

    Every point is additionally stamped with a monotone ``run_seq``
    (``max`` over the file's existing stamps, plus one — derived from
    file contents inside the same read-modify-replace cycle, so it is
    exactly as crash-safe as the append itself). The regression sentinel
    (``scripts/bench_regress.py``) orders a family's points by it instead
    of trusting wall-clock timestamps, which CI runners skew freely.
    """
    if not kind or not isinstance(kind, str):
        raise ValueError(f"bench_record needs a non-empty kind, got {kind!r}")
    path = pathlib.Path(os.environ.get("BENCH_DENOISE_PATH", _BENCH_PATH))
    records = []
    if path.exists():
        try:
            records = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            records = []
        if not isinstance(records, list):
            records = []
    _migrate_kinds(records)
    seq = 0
    for rec in records:
        if isinstance(rec, dict):
            s = rec.get("run_seq")
            if isinstance(s, (int, float)) and not isinstance(s, bool):
                seq = max(seq, int(s))
    records.append(
        {
            "name": name,
            "kind": kind,
            "timestamp": time.time(),
            "run_seq": seq + 1,
            **fields,
        }
    )
    fd, tmp = tempfile.mkstemp(
        dir=path.parent, prefix=f".{path.name}.", suffix=".tmp"
    )
    try:
        with os.fdopen(fd, "w") as f:
            f.write(json.dumps(records, indent=2) + "\n")
        os.replace(tmp, path)
    except BaseException:
        # never leave temp droppings next to the target on a failed write
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def bench_config(quick: bool, **kw) -> DenoiseConfig:
    base = dict(
        num_groups=PAPER_G,
        frames_per_group=200 if quick else PAPER_N,
        height=PAPER_H,
        width=PAPER_W,
        algorithm="alg3",
        backend="xla",
    )
    base.update(kw)
    return DenoiseConfig(**base)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


_report_headers_printed: set[str] = set()


def emit_report(name: str, report: StreamReport) -> None:
    """Print one full report CSV row (header once per report class).

    Carries every field ``report.row`` produces — elapsed/buffering/
    compute plus transfer_s, stall_s, overlap_frac and the ring-pipeline
    stage breakdown — so executor benchmarks never lose the overlap data
    to a truncated row again. The header comes from ``type(report)``, so
    subclasses with extra columns (``repro.serve.SessionReport``) emit
    *their* header rather than desyncing rows against the base one.
    Rows are prefixed ``report/`` to keep them distinguishable from the
    3-column ``emit`` rows in mixed output.
    """
    cls = type(report)
    if cls.__qualname__ not in _report_headers_printed:
        print(f"# {cls.header()}")
        _report_headers_printed.add(cls.__qualname__)
    print(f"report/{report.row(name)}")


def stream_pass_s(den, groups) -> float:
    """Wall seconds for one full ingest+finalize streaming pass over
    pre-staged device chunks — the shared timing body of the plan
    comparisons in ``table12_autotune`` and ``roofline_report`` (one
    implementation so their numbers stay comparable)."""
    t0 = time.perf_counter()
    state = den.init()
    for k, g in enumerate(groups):
        state = den.ingest(state, g, step=k)
    jax.block_until_ready(den.finalize(state))
    return time.perf_counter() - t0


def timeit(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall time (seconds) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))
