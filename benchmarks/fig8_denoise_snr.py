"""Paper Fig. 8: denoising efficacy with/without ambient-LED interference.

The static LED cancels in the pairwise subtraction and shot noise averages
down across groups — SNR of the averaged output should IMPROVE with G and
be insensitive to the ambient term.

Each measurement is also appended to ``BENCH_denoise.json`` as an ``snr``
point (via ``benchmarks.common.bench_record``), so denoising efficacy is
tracked across PRs alongside the throughput trajectories.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_config, bench_record, emit
from repro.core.denoise import StreamingDenoiser
from repro.data.prism import PrismSource, snr_db


def _snr_at(quick: bool, *, num_groups: int, ambient: bool, seed: int) -> float:
    """SNR of the averaged output for one (G, ambient) cell."""
    cfg = bench_config(quick, num_groups=num_groups, frames_per_group=50)
    src = PrismSource(cfg, ambient_on=ambient, seed=seed)
    den = StreamingDenoiser(cfg)
    out = np.asarray(den.run(g.astype(np.float32) for g in src.groups()))
    return snr_db(out, src.true_signal())


def run(quick: bool = True) -> None:
    for ambient in (True, False):
        snr = _snr_at(quick, num_groups=8, ambient=ambient, seed=1)
        # single-group (no averaging) comparison
        snr1 = _snr_at(quick, num_groups=1, ambient=ambient, seed=1)
        tag = "ambient_led" if ambient else "no_ambient"
        emit(
            f"fig8/{tag}",
            snr,
            f"snr_db_G8={snr:.2f};snr_db_G1={snr1:.2f};gain={snr - snr1:.2f}dB",
        )
        for groups, value in ((8, snr), (1, snr1)):
            bench_record(
                "snr",
                kind="snr",
                figure="fig8",
                config={"G": groups, "N": 50, "ambient": ambient},
                filter="pair_average",
                regime="none",
                snr_db=round(float(value), 3),
            )
