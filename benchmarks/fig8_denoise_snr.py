"""Paper Fig. 8: denoising efficacy with/without ambient-LED interference.

The static LED cancels in the pairwise subtraction and shot noise averages
down across groups — SNR of the averaged output should IMPROVE with G and
be insensitive to the ambient term.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_config, emit
from repro.core.denoise import StreamingDenoiser
from repro.data.prism import PrismSource, snr_db


def run(quick: bool = True) -> None:
    for ambient in (True, False):
        cfg = bench_config(quick, num_groups=8, frames_per_group=50)
        src = PrismSource(cfg, ambient_on=ambient, seed=1)
        den = StreamingDenoiser(cfg)
        out = np.asarray(den.run(g.astype(np.float32) for g in src.groups()))
        snr = snr_db(out, src.true_signal())
        # single-group (no averaging) comparison
        cfg1 = bench_config(quick, num_groups=1, frames_per_group=50)
        src1 = PrismSource(cfg1, ambient_on=ambient, seed=1)
        den1 = StreamingDenoiser(cfg1)
        out1 = np.asarray(den1.run(g.astype(np.float32) for g in src1.groups()))
        snr1 = snr_db(out1, src1.true_signal())
        tag = "ambient_led" if ambient else "no_ambient"
        emit(
            f"fig8/{tag}",
            snr,
            f"snr_db_G8={snr:.2f};snr_db_G1={snr1:.2f};gain={snr - snr1:.2f}dB",
        )
