"""Table 9 (framework extension): ring-buffer depth sweep.

The paper's §5 ping-pong buffering is a depth-2 ring; ``run_pipelined``
generalizes the depth. This table measures what depth buys: we replay a
pre-synthesized acquisition through ``run_pipelined`` at ring depths
1/2/3/4 under a *bursty* camera model — every ``BURST_EVERY``-th chunk's
readout takes ``BURST_COMPUTE_MULT`` (~4) compute-intervals extra
(frame-batch readout jitter, the case deeper rings exist for). A depth-1 ring serializes staging and compute; a
depth-2 (ping-pong) ring hides steady-state staging but surfaces each
burst as a compute stall; deeper rings bank chunks ahead during the fast
phase and ride the burst out.

Sweep: slot count x chunk size (frames per group N) x backend. The
``pallas`` column only runs on a real TPU — on CPU it would be the
interpreter, which benchmarks the emulation, not the kernel.

Per-depth speedups vs the depth-1 baseline and overlap fractions are
appended to ``BENCH_denoise.json`` as ``ring_depth_overlap`` points (see
docs/BENCHMARKS.md). On this host the expectation checked by the PR
acceptance criteria is: deeper rings (>= 3 slots) reach at least the
2-slot overlap fraction.
"""

from __future__ import annotations

import time
from typing import Iterator

import jax
import numpy as np

from benchmarks.common import (
    PAPER_H,
    PAPER_W,
    bench_config,
    bench_record,
    emit,
    emit_report,
)
from repro.core.streaming import run_pipelined
from repro.data.prism import PrismSource

DEPTHS = (1, 2, 3, 4)
BURST_EVERY = 4  # every 4th chunk is a slow readout ...
# ... taking ~2.5 compute-intervals extra: sized so a 2-slot (ping-pong)
# ring structurally cannot hide a burst (2 banked chunks < 2.5) but a
# 3-slot ring can (3 banked chunks >= 2.5) — and the deeper>=shallower
# overlap ordering survives host-speed drift in either direction
BURST_COMPUTE_MULT = 2.5


def bursty(chunks: list, burst_s: float, every: int = BURST_EVERY) -> Iterator:
    """Replay device-resident chunks with periodic readout bursts.

    The chunks are pre-committed to the device, like the paper's camera
    DMA-ing frames straight into DRAM banks: the producer's fast phase is
    then near-free and the only staging cost is the injected burst, so the
    sweep isolates ring *scheduling* — how much of a readout burst each
    depth can ride out on banked-ahead chunks — from host->device copy
    bandwidth (which table8 measures).
    """
    for i, chunk in enumerate(chunks):
        if i % every == every - 1:
            time.sleep(burst_s)
        yield chunk


def _measure_depths(cfg, chunks, iters=4):
    """Pooled-over-iters report per depth, iterations round-robined.

    Three choices against measurement noise on a small shared host:

    * round-robin: running all iterations of one depth back-to-back lets
      transient machine load (another process, turbo/thermal drift) land
      entirely on one depth and invert the depth-vs-overlap ordering;
      interleaving exposes every depth to the same drift.
    * per-cycle burst recalibration: the burst must stay ~2.5 compute-
      intervals (see BURST_COMPUTE_MULT) for the depth ordering to carry
      signal, but host compute speed drifts across seconds — a burst
      sized once can end up anywhere from ~1x to ~5x compute by the time
      a depth is measured. Each cycle re-times a no-burst replay and
      re-sizes the burst from it.
    * pooling, not best-of: per-depth stall/transfer/elapsed are *summed*
      across iterations and the overlap fraction computed from the pooled
      sums. Best-of/min-of selection amplifies each depth's lucky tail —
      one slow-compute iteration can hand the shallow baseline a near-1.0
      overlap.
    """
    from repro.core.streaming import StreamReport

    acc: dict[int, list] = {d: [] for d in DEPTHS}
    for _ in range(iters):
        t0 = time.perf_counter()
        run_pipelined(cfg, iter(chunks), num_slots=1)  # calibrate this cycle
        burst_s = max(
            BURST_COMPUTE_MULT * (time.perf_counter() - t0) / len(chunks), 0.004
        )
        for depth in DEPTHS:
            _, rep = run_pipelined(
                cfg, bursty(chunks, burst_s), num_slots=depth, policy="block"
            )
            acc[depth].append(rep)
    pooled = {}
    for depth, reps in acc.items():
        pooled[depth] = StreamReport(
            elapsed_s=sum(r.elapsed_s for r in reps),
            buffering_s=0.0,
            compute_s=sum(r.compute_s for r in reps),
            frames=sum(r.frames for r in reps),
            bytes_in=sum(r.bytes_in for r in reps),
            transfer_s=sum(r.transfer_s for r in reps),
            stall_s=sum(r.stall_s for r in reps),
            num_slots=depth,
            produce_wait_s=sum(r.produce_wait_s for r in reps),
            drops=sum(r.drops for r in reps),
            ring_occupancy_mean=sum(r.ring_occupancy_mean for r in reps)
            / len(reps),
            ring_occupancy_max=max(r.ring_occupancy_max for r in reps),
        )
    return pooled


def run(quick: bool = True) -> None:
    backends = ["xla"] + (["pallas"] if jax.default_backend() == "tpu" else [])
    # chunk compute must dwarf time.sleep/scheduler jitter for the depth
    # ordering to be stable on a small host — N >= 400 in both modes
    chunk_sizes = (400, 800) if quick else (400, 1000)
    for backend in backends:
        for n in chunk_sizes:
            cfg = bench_config(
                quick,
                num_groups=24,  # 6 bursts per replay: averages burst noise
                frames_per_group=n,
                height=PAPER_H,
                width=PAPER_W,
                backend=backend,
            )
            chunks = [
                jax.device_put(np.asarray(c)) for c in PrismSource(cfg).groups()
            ]
            jax.block_until_ready(chunks)

            run_pipelined(cfg, iter(chunks[:2]), num_slots=1)  # warm the jit
            reports = _measure_depths(cfg, chunks)
            base = reports[DEPTHS[0]]
            for d in DEPTHS:
                rep = reports[d]
                speedup = base.elapsed_s / max(rep.elapsed_s, 1e-9)
                tag = f"table9/{backend}/N{n}/slots{d}"
                emit(
                    tag,
                    rep.elapsed_s * 1e6 / rep.frames,
                    f"speedup_vs_slots1={speedup:.2f}x;"
                    f"overlap_frac={rep.overlap_frac:.2f};"
                    f"stall_s={rep.stall_s:.3f};"
                    f"occ_mean={rep.ring_occupancy_mean:.2f}",
                )
                emit_report(tag, rep)
                if d == 1:
                    continue  # the baseline itself is not a speedup point
                bench_record(
                    "ring_depth_overlap",
                    kind="speedup",
                    config={
                        "G": cfg.num_groups,
                        "N": n,
                        "H": cfg.height,
                        "W": cfg.width,
                        "backend": backend,
                        "slots": d,
                        "policy": "block",
                        "burst_every": BURST_EVERY,
                        "burst_compute_mult": BURST_COMPUTE_MULT,
                    },
                    baseline="run_pipelined num_slots=1 (serial ring)",
                    candidate=f"run_pipelined num_slots={d}",
                    baseline_s=round(base.elapsed_s, 4),
                    candidate_s=round(rep.elapsed_s, 4),
                    speedup=round(speedup, 3),
                    overlap_frac=round(rep.overlap_frac, 3),
                    stall_s=round(rep.stall_s, 4),
                    produce_wait_s=round(rep.produce_wait_s, 4),
                    ring_occupancy_mean=round(rep.ring_occupancy_mean, 2),
                )
