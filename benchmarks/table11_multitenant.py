"""Table 11 (framework extension): multi-tenant session scheduling.

The deployments measured so far serve one stream per executor. This table
measures what the ``repro.serve`` session service buys when many tenants
share the device: sessions × scheduler (QoS) policy × filter mix, each
session replaying pre-synthesized device-resident chunks through the
table9 bursty-readout model (every ``BURST_EVERY``-th chunk's readout
stalls ~``BURST_COMPUTE_MULT`` compute-intervals — camera readout the
device must ride out).

* **baseline** — today's deployment: the same sessions run back-to-back,
  one ``run_pipelined`` each (every run still overlaps its own staging
  with its own compute; the sequence just cannot overlap tenants).
* **candidate** — one ``SessionScheduler`` hosting all sessions
  concurrently: tenant readout stalls overlap each other, and compatible
  sessions fold through ONE banked device step per group (stacked along
  the filter state's bank/slot axis).

Appended to ``BENCH_denoise.json`` as ``multitenant`` points: aggregate
fps, speedup vs sequential (block cells), per-session p99 service
latency, Jain fairness over per-session throughput, drop/deadline-miss
accounting (real-time cells). Acceptance on this host: >= 1.5x aggregate
throughput at 4 uniform block-mode sessions vs 4 sequential runs.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import (
    PAPER_H,
    PAPER_W,
    bench_config,
    bench_record,
    emit,
    emit_report,
)
from benchmarks.table9_ring_depth import bursty
from repro.core.streaming import run_pipelined
from repro.data.prism import PrismSource
from repro.serve import Session, SessionScheduler

SESSION_COUNTS = (1, 2, 4)
BURST_EVERY = 2      # every 2nd chunk pays a readout stall ...
# ... of ~10 compute-intervals: readout-dominated tenants, the case a
# shared scheduler exists for (sequential runs serialize the stalls;
# co-scheduled sessions ride them out under each other's compute). Sized
# so the per-session stream stays readout-bound even when 4 sessions
# share this host's cores — smaller multiples turn the 4-session cell
# compute-bound and measure XLA core contention instead of scheduling,
# and leave the headline at the mercy of host-load drift.
BURST_COMPUTE_MULT = 10.0
RING_SLOTS = 3       # per-session staging depth (rides one burst)
REPEATS = 2          # block cells: candidate/baseline round-robined and
                     # pooled, so transient host load lands on both sides


def _jain(xs: list[float]) -> float:
    """Jain fairness index over per-session throughput: 1.0 = perfectly
    even, 1/n = one session starved the rest. All-zero throughput is
    degenerate evenness -> 1.0 (and must not divide by zero)."""
    denom = len(xs) * sum(x * x for x in xs)
    if not denom:
        return 1.0
    return (sum(xs) ** 2) / denom


def _mix_configs(cfg, mix: str, n: int):
    """Per-session configs for a cell. ``uniform`` co-batches everything
    on one executor; ``mixed`` alternates filters, exercising the
    stream_key split across the executor pool."""
    if mix == "uniform":
        return [cfg] * n
    return [
        cfg
        if i % 2 == 0
        else dataclasses.replace(cfg, filter_name="ema_variance")
        for i in range(n)
    ]


def _measure_cell(configs, chunks, burst_s, policy, deadline_ms):
    """One scheduler run hosting ``len(configs)`` sessions; returns
    (wall_s, reports)."""
    n = len(configs)
    uniform = len({c.filter_name for c in configs}) == 1
    sched = SessionScheduler(
        slots_per_executor=n if uniform else max(2, (n + 1) // 2),
        max_executors=1 if uniform else 2,
        max_sessions=n,
    )
    try:
        t0 = time.perf_counter()
        handles = [
            sched.submit(
                Session(
                    config=c,
                    source=bursty(chunks, burst_s, every=BURST_EVERY),
                    name=f"t{i}",
                    mode=policy,
                    deadline_ms=deadline_ms,
                    num_slots=RING_SLOTS,
                )
            )
            for i, c in enumerate(configs)
        ]
        reports = [h.result(timeout=600)[1] for h in handles]
        wall = time.perf_counter() - t0
    finally:
        sched.shutdown()
    return wall, reports


def run(quick: bool = True) -> None:
    cfg = bench_config(
        quick,
        num_groups=12,  # 6 bursts per replay: averages burst noise
        frames_per_group=240 if quick else 600,
        height=PAPER_H,
        width=PAPER_W,
    )
    ema_cfg = dataclasses.replace(cfg, filter_name="ema_variance")
    chunks = [jax.device_put(np.asarray(c)) for c in PrismSource(cfg).groups()]
    jax.block_until_ready(chunks)

    # warm every jit path the cells hit (single-bank step for both
    # filters, plus the batched cohort shapes), then calibrate the burst
    # against this host's current per-chunk compute, like table9
    run_pipelined(cfg, iter(chunks[:2]), num_slots=1)
    run_pipelined(ema_cfg, iter(chunks[:2]), num_slots=1)
    for n in SESSION_COUNTS:
        _measure_cell([cfg] * n, chunks[:3], 0.0, "block", None)
    t0 = time.perf_counter()
    run_pipelined(cfg, iter(chunks), num_slots=1)
    per_chunk_s = (time.perf_counter() - t0) / len(chunks)
    burst_s = max(BURST_COMPUTE_MULT * per_chunk_s, 0.004)

    def sequential_baseline(configs):
        t0 = time.perf_counter()
        for c in configs:
            run_pipelined(
                c,
                bursty(chunks, burst_s, every=BURST_EVERY),
                num_slots=RING_SLOTS,
                policy="block",
            )
        return time.perf_counter() - t0

    cells = [("uniform", "block", n) for n in SESSION_COUNTS]
    cells += [("uniform", "drop_oldest", max(SESSION_COUNTS))]
    cells += [("mixed", "block", max(SESSION_COUNTS))]

    for mix, policy, n in cells:
        configs = _mix_configs(cfg, mix, n)
        deadline_ms = (
            max(1.0, burst_s * 1e3) if policy == "drop_oldest" else None
        )
        # round-robin candidate/baseline and pool sums (table9's recipe):
        # back-to-back repeats hand transient host load to one side only.
        # Latency/fairness/drop stats pool over EVERY repeat's reports —
        # a spike must land in the same statistics as the wall time it
        # inflated, or the point mixes pooled and single-repeat numbers.
        tag = f"table11/{mix}/{policy}/n{n}"
        wall = base_s = 0.0
        frames_total = 0
        pooled = []
        for rep_i in range(REPEATS if policy == "block" else 1):
            w, reports = _measure_cell(
                configs, chunks, burst_s, policy, deadline_ms
            )
            wall += w
            frames_total += sum(r.frames for r in reports)
            pooled.extend(reports)
            for r in reports:
                emit_report(f"{tag}/r{rep_i}/{r.session}", r)
            if policy == "block":
                base_s += sequential_baseline(configs)
        agg_fps = frames_total / wall
        per_fps = [r.frames / max(r.elapsed_s, 1e-9) for r in pooled]
        fairness = _jain(per_fps)
        p99 = max(r.latency_p99_ms for r in pooled)
        drops = sum(r.drops for r in pooled)
        misses = sum(r.deadline_misses for r in pooled)

        point = dict(
            config={
                "G": cfg.num_groups,
                "N": cfg.frames_per_group,
                "H": cfg.height,
                "W": cfg.width,
                "backend": cfg.backend,
                "sessions": n,
                "policy": policy,
                "mix": mix,
                "ring_slots": RING_SLOTS,
                "burst_every": BURST_EVERY,
                "burst_compute_mult": BURST_COMPUTE_MULT,
            },
            candidate=f"SessionScheduler, {n} concurrent sessions",
            candidate_s=round(wall, 4),
            aggregate_fps=round(agg_fps, 1),
            session_p99_ms=round(p99, 3),
            fairness=round(fairness, 3),
            drops=drops,
            deadline_misses=misses,
        )
        derived = (
            f"agg_fps={agg_fps:.0f};p99_ms={p99:.1f};"
            f"fairness={fairness:.2f};drops={drops}"
        )
        if policy == "block":
            speedup = base_s / max(wall, 1e-9)
            point.update(
                baseline=(
                    f"{n} sequential run_pipelined runs "
                    f"(pooled over {REPEATS} repeats)"
                ),
                baseline_s=round(base_s, 4),
                speedup=round(speedup, 3),
            )
            derived += f";speedup_vs_sequential={speedup:.2f}x"
        emit(tag, wall * 1e6 / max(frames_total, 1), derived)
        bench_record("multitenant", kind="multitenant", **point)
