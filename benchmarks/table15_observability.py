"""Table 15 (framework extension): observability overhead + sample trace.

The telemetry layer (``repro.obs``) claims its disabled mode is a no-op:
``run_pipelined``'s per-chunk spans collapse to one preallocated null
context manager and its counters to a dict-get + float-add. This table
measures that claim with the repo's paired-ratio discipline (order-
balanced A/B repeats, per-pair ratios, median — the table9/table12
idiom) on the bursty-readout replay:

* ``ratio_disabled`` — ``run_pipelined`` (tracer disabled, the
  production default) vs a benchmark-local *telemetry-free replica* of
  the same 2-stage pipeline (same ring, same staging thread, same fold
  calls, zero obs/metrics calls). This is the cost every user pays.
* ``ratio_enabled``  — tracer enabled vs disabled: what turning the
  trace ring on costs.
* ``span_ns``        — direct per-call cost of the disabled
  ``obs.span()`` fast path.

``--assert-overhead`` exits non-zero unless the disabled-mode median
paired ratio stays <= ``OVERHEAD_BUDGET`` (1.02). The replica's output
is checked bit-identical to ``run_pipelined``'s before any timing is
trusted.

The table also emits a *sample trace artifact*: an enabled-mode
4-session fleet run with one injected executor kill, exported as
Chrome-trace JSON (``--trace-out``, default ``table15_trace.json``) and
schema-validated in-process — load it at chrome://tracing or
https://ui.perfetto.dev. Run directly for the CI smoke cycle::

    python -m benchmarks.table15_observability --smoke --assert-overhead
"""

from __future__ import annotations

import argparse
import math
import statistics
import tempfile
import threading
import time
from typing import Sequence

import jax
import numpy as np

from benchmarks.common import (
    PAPER_N,
    bench_config,
    bench_record,
    emit,
)
from benchmarks.table9_ring_depth import bursty
from repro import obs
from repro.core.denoise import StreamingDenoiser
from repro.core.ringbuf import RingBuffer, RingClosed
from repro.core.streaming import run_pipelined
from repro.data.prism import PrismSource
from repro.serve import FaultPlan, FleetScheduler, Session

RING_SLOTS = 2
OVERHEAD_BUDGET = 1.02   # disabled-mode median paired ratio ceiling
BURST_COMPUTE_MULT = 2.5  # same bursty-readout shape table9 sweeps
BURST_EVERY = 4
SPAN_CALLS = 100_000     # disabled-path microbench population
KILL_AT_STEP = 3  # one fold past the every-2 checkpoint: recovery must replay


def _control_pipeline(cfg, source, num_slots=RING_SLOTS):
    """Obs-free replica of ``run_pipelined``'s 2-stage pipeline with the
    *hand-maintained* accounting the metrics registry replaced.

    Same ring, same staging thread, same per-step fold and finalize, and
    the same bookkeeping the pre-telemetry executor carried (per-chunk
    transfer timing, frame counting, dwell samples, end-of-run percentile
    columns) — kept as plain locals instead of registry instruments. The
    paired ratio against this isolates what routing that accounting
    through ``repro.obs`` (plus the disabled-mode span calls) costs,
    which is exactly the disabled-path contract under test. Returns
    ``(out, elapsed_s)``.
    """
    den = StreamingDenoiser(cfg)
    ring = RingBuffer(num_slots)
    source = iter(source)
    errors: list[BaseException] = []

    def produce():
        try:
            while True:
                t0 = time.perf_counter()
                try:
                    chunk = next(source)
                except StopIteration:
                    break
                dev = jax.device_put(jax.numpy.asarray(chunk))
                jax.block_until_ready(dev)
                ring.put((dev, time.perf_counter() - t0))
        except RingClosed:
            pass
        except BaseException as e:
            errors.append(e)
        finally:
            ring.close()

    t0 = time.perf_counter()
    state = den.init()
    step = frames = 0
    transfer_s = 0.0
    latencies: list[float] = []
    producer = threading.Thread(target=produce, name="control-stage", daemon=True)
    producer.start()
    try:
        while True:
            try:
                dev, dt = ring.get()
            except RingClosed:
                break
            transfer_s += dt
            latencies.append(ring.stats.last_dwell_s)
            state = den.ingest(state, dev, step=step)
            frames += math.prod(dev.shape[:-2])
            step += 1
    finally:
        ring.close()
        producer.join()
    if errors:
        raise errors[0]
    out = den.finalize(state)
    jax.block_until_ready(out)
    elapsed = time.perf_counter() - t0
    # the hand-rolled report columns the snapshot-derived path replaced
    _ = {
        "frames": frames,
        "bytes_in": frames * cfg.bytes_per_frame,
        "transfer_s": transfer_s,
        "stall_s": ring.stats.get_wait_s,
        "p50_ms": obs.nearest_rank(latencies, 50.0) * 1e3,
        "p99_ms": obs.nearest_rank(latencies, 99.0) * 1e3,
    }
    return out, elapsed


def _calibrate_burst_s(cfg, chunks) -> float:
    """Size the readout burst in compute-intervals, like table9."""
    den = StreamingDenoiser(cfg)
    state = den.init()
    state = den.ingest(state, chunks[0], step=0)  # warm the jit cache
    t0 = time.perf_counter()
    for k, g in enumerate(chunks):
        state = den.ingest(state, g, step=k + 1)
    jax.block_until_ready(den.partial(state, len(chunks)))
    per_chunk = (time.perf_counter() - t0) / len(chunks)
    return BURST_COMPUTE_MULT * per_chunk


def _paired_ratios(run_a, run_b, pairs: int, k: int = 4):
    """Order-balanced min-of-``k`` paired ratios b/a, plus the floor ratio.

    Each pair interleaves ``k`` runs of each side (alternating which goes
    first) and takes the per-side *minimum* before forming the ratio: on
    a shared host the run-time distribution is floor + contention spikes,
    and the telemetry delta under test lives at the floor — medians of
    single runs would measure the machine, not the layer. Order balance
    spreads slow drift across both sides. Returns ``(ratios, floor)``
    where ``floor`` is the global-min ratio over every interleaved run —
    the most drift-immune single estimate (a load spike that lands on
    *both* sides of a late pair inflates that pair's ratio but cannot
    touch the global floors), so it is what ``--assert-overhead`` gates
    on while the per-pair ratios populate the recorded distribution."""
    ratios = []
    all_a, all_b = [], []
    for i in range(pairs):
        ta, tb = [], []
        for j in range(k):
            if (i + j) % 2 == 0:
                ta.append(run_a())
                tb.append(run_b())
            else:
                tb.append(run_b())
                ta.append(run_a())
        ratios.append(min(tb) / min(ta))
        all_a += ta
        all_b += tb
    return ratios, min(all_b) / min(all_a)


def _span_fast_path_ns() -> float:
    """Per-call cost of the disabled ``obs.span()`` path."""
    tr = obs.get_tracer()
    assert not tr.enabled, "microbench must run against the disabled tracer"
    span = obs.span
    t0 = time.perf_counter()
    for _ in range(SPAN_CALLS):
        with span("bench.noop", "bench"):
            pass
    return (time.perf_counter() - t0) / SPAN_CALLS * 1e9


def _trace_artifact(cfg, chunks, path: str, ckpt_dir: str) -> dict:
    """Enabled-mode 4-session fleet run with one injected kill, exported
    as validated Chrome-trace JSON. Returns summary stats."""
    tr = obs.get_tracer()
    was_enabled = tr.enabled
    tr.clear()
    obs.configure(enabled=True)
    plan = FaultPlan().crash("ex0", at_step=KILL_AT_STEP)
    fleet = FleetScheduler(
        checkpoint_dir=ckpt_dir,
        checkpoint_every=2,  # sparse: the recovery replays past a snapshot
        faults=plan,
        slots_per_executor=2,
        max_executors=2,
        max_sessions=4,
    )
    try:
        handles = [
            fleet.submit(
                Session(
                    config=cfg,
                    source=iter(chunks),
                    name=f"s{i}",
                    num_slots=RING_SLOTS,
                )
            )
            for i in range(4)
        ]
        reports = [h.result(timeout=600)[1] for h in handles]
    finally:
        fleet.shutdown()
        doc = tr.export_chrome(path)
        obs.configure(enabled=was_enabled)
        tr.clear()
    events = obs.validate_chrome_trace(doc)
    names = {e["name"] for e in events}
    # the crash path: executor-dead (not heartbeat/evict, which need a
    # fake clock — the test suite covers that sequence) -> restore -> replay
    required = {"fleet.executor_dead", "fleet.restore", "serve.replay",
                "fleet.checkpoint", "serve.submit", "serve.join"}
    missing = required - names
    if missing:
        raise SystemExit(
            f"trace artifact missing expected events: {sorted(missing)}"
        )
    return {
        "events": len(events),
        "restarts": sum(r.restarts for r in reports),
        "sessions": len(reports),
    }


def run(
    quick: bool = True,
    *,
    smoke: bool = False,
    assert_overhead: bool = False,
    trace_out: str = "table15_trace.json",
) -> None:
    # paper-shaped chunks even in smoke: the <= 2% contract is stated at
    # paper defaults, and tiny frames would measure Python dispatch jitter
    # rather than the telemetry layer (per-chunk fold must dwarf the
    # per-chunk accounting for the ratio to carry signal)
    cfg = bench_config(
        quick,
        num_groups=6 if smoke else 8,
        frames_per_group=200 if (smoke or quick) else PAPER_N,
    )
    chunks = [jax.device_put(np.asarray(c)) for c in PrismSource(cfg).groups()]
    jax.block_until_ready(chunks)
    burst_s = _calibrate_burst_s(cfg, chunks)
    pairs = 5 if smoke else 6

    # -- bit-identity gate: the replica must compute the same stream ---------
    ref, _ = run_pipelined(cfg, iter(chunks), num_slots=RING_SLOTS)
    out, _ = _control_pipeline(cfg, iter(chunks))
    if not np.array_equal(np.asarray(out), np.asarray(ref)):
        raise SystemExit("control replica diverged from run_pipelined")

    def timed_control() -> float:
        _, dt = _control_pipeline(cfg, bursty(chunks, burst_s, BURST_EVERY))
        return dt

    def timed_pipelined() -> float:
        t0 = time.perf_counter()
        run_pipelined(
            cfg, bursty(chunks, burst_s, BURST_EVERY), num_slots=RING_SLOTS
        )
        return time.perf_counter() - t0

    # -- disabled mode: the cost every user pays -----------------------------
    tr = obs.get_tracer()
    was_enabled = tr.enabled
    obs.configure(enabled=False)
    try:
        ratios_disabled, floor_disabled = _paired_ratios(
            timed_control, timed_pipelined, pairs
        )
        span_ns = _span_fast_path_ns()
        # -- enabled mode: what turning the trace ring on costs --------------
        def timed_enabled() -> float:
            obs.configure(enabled=True)
            try:
                return timed_pipelined()
            finally:
                obs.configure(enabled=False)
                tr.clear()

        ratios_enabled, floor_enabled = _paired_ratios(
            timed_pipelined, timed_enabled, pairs
        )
    finally:
        obs.configure(enabled=was_enabled)
        tr.clear()

    med_disabled = statistics.median(ratios_disabled)
    med_enabled = statistics.median(ratios_enabled)
    emit(
        "table15/overhead",
        span_ns * 1e-3,
        f"ratio_disabled={med_disabled:.4f};floor_disabled={floor_disabled:.4f};"
        f"ratio_enabled={med_enabled:.4f};span_ns={span_ns:.0f}",
    )

    # -- sample trace artifact ----------------------------------------------
    # small frames: the artifact documents the *event vocabulary* of a
    # kill + recovery, which is shape-independent — no reason to drag
    # paper-sized chunks through a 4-session fleet here
    art_cfg = bench_config(
        True, num_groups=6, frames_per_group=40, height=16, width=64
    )
    art_chunks = [
        jax.device_put(np.asarray(c)) for c in PrismSource(art_cfg).groups()
    ]
    jax.block_until_ready(art_chunks)
    with tempfile.TemporaryDirectory(prefix="table15-ckpt-") as root:
        artifact = _trace_artifact(art_cfg, art_chunks, trace_out, f"{root}/ckpt")
    emit(
        "table15/trace",
        0.0,
        f"path={trace_out};events={artifact['events']};"
        f"restarts={artifact['restarts']}",
    )

    bench_record(
        "obs_overhead",
        kind="obs_overhead",
        config={
            "G": cfg.num_groups,
            "N": cfg.frames_per_group,
            "H": cfg.height,
            "W": cfg.width,
            "backend": cfg.backend,
            "ring_slots": RING_SLOTS,
            "pairs": pairs,
            "burst_every": BURST_EVERY,
            "burst_compute_mult": BURST_COMPUTE_MULT,
        },
        ratio_disabled=round(med_disabled, 4),
        floor_disabled=round(floor_disabled, 4),
        ratio_enabled=round(med_enabled, 4),
        floor_enabled=round(floor_enabled, 4),
        span_ns=round(span_ns, 1),
        trace_events=artifact["events"],
    )

    if assert_overhead:
        # two independent estimators of the same delta: the pair-ratio
        # median and the global floor ratio. Host noise (a contention
        # spike, one lucky run) moves them in *different* directions; a
        # real systematic overhead moves both up. Gate on the smaller so
        # a shared-runner hiccup cannot fail the build while a genuine
        # >2% regression still trips both.
        estimate = min(med_disabled, floor_disabled)
        if estimate > OVERHEAD_BUDGET:
            raise SystemExit(
                f"disabled-mode telemetry overhead {estimate:.4f} "
                f"(median {med_disabled:.4f}, floor {floor_disabled:.4f}, "
                f"pairs {ratios_disabled}) exceeds budget {OVERHEAD_BUDGET}"
            )
        print(
            f"# overhead assertion ok: disabled ratio {estimate:.4f} "
            f"<= {OVERHEAD_BUDGET} (median {med_disabled:.4f}, floor "
            f"{floor_disabled:.4f}), span fast path {span_ns:.0f}ns"
        )


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale streams")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny stream, fewer pairs — the CI cycle",
    )
    ap.add_argument(
        "--assert-overhead",
        action="store_true",
        help="exit non-zero unless the disabled-mode floor paired ratio "
        f"stays <= {OVERHEAD_BUDGET}",
    )
    ap.add_argument(
        "--trace-out",
        default="table15_trace.json",
        help="where to write the sample Chrome-trace artifact",
    )
    args = ap.parse_args(argv)
    run(
        quick=not args.full,
        smoke=args.smoke,
        assert_overhead=args.assert_overhead,
        trace_out=args.trace_out,
    )


if __name__ == "__main__":
    main()
