"""Paper Table 1: kernel latency per algorithm.

Two views: (a) measured wall time of the dataflow-faithful XLA kernels on
this host, (b) the paper's exact analytic per-frame latencies (µs) from
``core.latency_model`` — the HLS-report reproduction.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_config, emit, timeit
from repro.core import latency_model as lm
from repro.kernels import ops


def run(quick: bool = True) -> None:
    cfg = bench_config(quick)
    rng = np.random.default_rng(0)
    frames = rng.integers(
        0, 4096, (cfg.num_groups, cfg.frames_per_group, cfg.height, cfg.width)
    ).astype(np.float32)
    total_frames = cfg.num_groups * cfg.frames_per_group
    import jax.numpy as jnp

    x = jnp.asarray(frames)
    for alg in ("alg1", "alg2", "alg3", "alg3_v2"):
        t = timeit(
            lambda a=alg: ops.subtract_average(
                x, offset=cfg.offset, algorithm=a, backend="xla"
            )
        )
        emit(
            f"table1/{alg}/host_wall",
            t * 1e6 / total_frames,
            f"per-frame;total_s={t:.4f}",
        )
    # paper analytic model (exact reproduction of §6 numbers)
    for alg in ("alg1", "alg2", "alg3"):
        lat = lm.frame_latencies_us(alg)
        worst = max(lat.values())
        emit(
            f"table1/{alg}/paper_model_worst_frame",
            worst,
            f"phases={';'.join(f'{k}={v:.3f}' for k, v in lat.items())}",
        )
    emit(
        "table1/realtime_threshold",
        lm.PaperConstants().inter_frame_us,
        "camera inter-frame interval",
    )
