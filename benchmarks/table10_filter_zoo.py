"""Filter zoo (framework extension of the paper's Fig. 8): sweep the
streaming-filter registry × backend × noise regime.

For every registered filter (``repro.denoise.FILTERS``) this measures

* **throughput** — wall time of the one-shot denoise at the bench config,
  appended to ``BENCH_denoise.json`` as ``filter_zoo`` points with
  ``kind="throughput"``;
* **SNR** — against the noise-free expectation under each
  ``PrismSource`` noise regime (``none`` / ``hot_pixels`` / ``impulse`` /
  ``drift``), appended as ``filter_zoo`` points with ``kind="snr"``.

It also records the headline comparison the subsystem exists for:
``filter_zoo_median_vs_mean_impulse`` — temporal-median vs the paper's
mean-average under impulse/cosmic-ray noise, where the rank filter
rejects spikes the average can only smear (expected gain: several dB).

The ``pallas`` column only runs natively on TPU; on CPU the kernels would
execute in interpret mode (orders of magnitude slower, validating the
body, not the speed — the test suite already covers that), so off-TPU the
sweep is ``xla`` only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, bench_record, emit, timeit
from repro.core.denoise import DenoiseConfig, StreamingDenoiser
from repro.data.prism import NOISE_REGIMES, PrismSource, snr_db
from repro.denoise import FILTERS


def _zoo_config(quick: bool, **kw) -> DenoiseConfig:
    # smaller-than-paper frames in quick mode: the zoo is a 4x4x|B| sweep
    if quick:
        kw.setdefault("height", 40)
        kw.setdefault("width", 128)
        kw.setdefault("frames_per_group", 60)
    return bench_config(quick, **kw)


def run(quick: bool = True) -> None:
    backends = ("pallas", "xla") if jax.default_backend() == "tpu" else ("xla",)
    snr_by = {}
    for name in sorted(FILTERS):
        for backend in backends:
            cfg = _zoo_config(quick, filter_name=name, backend=backend)
            den = StreamingDenoiser(cfg)
            frames = jnp.asarray(
                PrismSource(cfg, seed=2).all_frames().astype(np.float32)
            )
            sec = timeit(den, frames)
            emit(f"table10/{name}/{backend}", sec * 1e6, "one_shot")
            bench_record(
                "filter_zoo",
                kind="throughput",
                config={
                    "G": cfg.num_groups,
                    "N": cfg.frames_per_group,
                    "H": cfg.height,
                    "W": cfg.width,
                    "backend": backend,
                },
                filter=name,
                us_per_call=round(sec * 1e6, 1),
                mb_per_s=round(cfg.input_bytes / 1e6 / sec, 1),
            )
            for regime in NOISE_REGIMES:
                src = PrismSource(cfg, seed=2, noise_regime=regime)
                out = np.asarray(
                    den(jnp.asarray(src.all_frames().astype(np.float32)))
                )
                snr = float(snr_db(out, src.true_signal()))
                snr_by[(name, backend, regime)] = snr
                emit(
                    f"table10/{name}/{backend}/{regime}",
                    snr,
                    f"snr_db={snr:.2f}",
                )
                bench_record(
                    "filter_zoo",
                    kind="snr",
                    config={
                        "G": cfg.num_groups,
                        "N": cfg.frames_per_group,
                        "H": cfg.height,
                        "W": cfg.width,
                        "backend": backend,
                    },
                    filter=name,
                    regime=regime,
                    snr_db=round(snr, 3),
                )

    # headline: rank filtering beats averaging under impulse noise
    backend = backends[-1]
    mean_snr = snr_by[("pair_average", backend, "impulse")]
    median_snr = snr_by[("temporal_median", backend, "impulse")]
    emit(
        "table10/median_vs_mean_impulse",
        median_snr - mean_snr,
        f"median_db={median_snr:.2f};mean_db={mean_snr:.2f}",
    )
    bench_record(
        "filter_zoo_median_vs_mean_impulse",
        kind="snr_gain",
        config={"backend": backend},
        baseline="pair_average (paper subtract-and-average)",
        candidate="temporal_median (sliding-window rank filter)",
        baseline_snr_db=round(mean_snr, 3),
        candidate_snr_db=round(median_snr, 3),
        gain_db=round(median_snr - mean_snr, 3),
    )
