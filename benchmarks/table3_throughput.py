"""Paper Table 3: throughput under software trigger (max camera rate).

Streams the synthetic PRISM acquisition group-by-group through each
algorithm's streaming dataflow: Alg 3 folds into the running sum; Alg 1/2
stage difference frames into a tmpFrame buffer and reduce at the end.

The sweep covers the backend × staging matrix this PR opened up:

* ``no_burst``      — Alg 1/2 dataflow (materialize diffs, reduce late).
* ``burst_rw_f32``  — the pre-PR Alg 3 ingest: host-side f32 convert,
  synchronous ``jnp.asarray`` staging, one XLA step per group.
* ``burst_rw_u16``  — u16 containers straight to device (convert fuses
  into the step), still synchronous.
* ``prefetch_u16``  — the new double-buffered executor (``run_inline``):
  u16 staging overlapped under compute. This is the production path; its
  speedup over ``burst_rw_f32`` is recorded to BENCH_denoise.json.
* ``pallas[pt=..]`` — the Pallas streaming kernel (interpret mode on CPU)
  across pair-tile sizes, validating the pair-tiling knob end to end.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    PAPER_G,
    PAPER_H,
    PAPER_N,
    PAPER_W,
    bench_config,
    bench_record,
    emit,
)
from repro.core.denoise import DenoiseConfig
from repro.core.streaming import run_inline
from repro.data.prism import PrismSource
from repro.kernels import ops


def _stream_alg3_f32(cfg, groups):
    """Pre-PR ingest: host f32 convert + sync staging + per-group XLA step."""
    t0 = time.perf_counter()
    state = ops.stream_init(cfg.frames_per_group, cfg.height, cfg.width)
    for gf in groups:
        state = ops.stream_step(
            state, jnp.asarray(gf.astype(np.float32)),
            num_groups=cfg.num_groups, offset=cfg.offset, backend="xla",
        )
    out = ops.stream_finalize(state, cfg.num_groups)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _stream_alg3_u16(cfg, groups):
    """u16 containers to device; the convert fuses into the step."""
    t0 = time.perf_counter()
    state = ops.stream_init(cfg.frames_per_group, cfg.height, cfg.width)
    for gf in groups:
        state = ops.stream_step(
            state, jnp.asarray(gf),
            num_groups=cfg.num_groups, offset=cfg.offset, backend="xla",
        )
    out = ops.stream_finalize(state, cfg.num_groups)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _stream_prefetch(cfg, groups):
    """The new double-buffered executor over pre-staged camera frames."""
    t0 = time.perf_counter()
    _, rep = run_inline(cfg, iter(groups), prefetch=True)
    del rep
    return time.perf_counter() - t0


def _stream_pallas(cfg, groups, pair_tile):
    t0 = time.perf_counter()
    state = ops.stream_init(cfg.frames_per_group, cfg.height, cfg.width)
    for gf in groups:
        state = ops.multibank_stream_step(
            state[None], jnp.asarray(gf)[None],
            num_groups=cfg.num_groups, offset=cfg.offset, backend="pallas",
            pair_tile=pair_tile,
        )[0]
    out = ops.stream_finalize(state, cfg.num_groups)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _stream_materialized(cfg, groups):
    """Alg 1/2 dataflow: store per-group diffs, reduce after the last."""
    t0 = time.perf_counter()
    p = cfg.pairs_per_group

    @jax.jit
    def diff(gf):
        pr = gf.reshape(p, 2, cfg.height, cfg.width)
        return pr[:, 1].astype(jnp.float32) - pr[:, 0].astype(jnp.float32) + cfg.offset

    tmp = jnp.zeros((cfg.num_groups, p, cfg.height, cfg.width), jnp.float32)
    for gi, gf in enumerate(groups):
        tmp = tmp.at[gi].set(diff(jnp.asarray(gf)))
    out = tmp.sum(0) / cfg.num_groups
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def run(quick: bool = True) -> None:
    cfg = bench_config(quick)
    src = PrismSource(cfg)
    groups = list(src.groups())
    frames = cfg.num_groups * cfg.frames_per_group
    mb = frames * cfg.frame_pixels * 2 / 1e6
    variants = [
        ("no_burst(alg1-dataflow)", _stream_materialized),
        ("burst_rw_f32(pre-PR)", _stream_alg3_f32),
        ("burst_rw_u16", _stream_alg3_u16),
        ("prefetch_u16", _stream_prefetch),
    ]
    for pt in (1, None):
        label = f"pallas[pt={pt or 'auto'}]"
        variants.append(
            (label, lambda c, g, _pt=pt: _stream_pallas(c, g, _pt))
        )
    for name, fn in variants:
        t = min(fn(cfg, groups) for _ in range(2))
        emit(
            f"table3/{name}",
            t * 1e6 / frames,
            f"fps={frames / t:.0f};MBps={mb / t:.1f}",
        )
    # paper hardware reference points
    emit("table3/paper_fpga_alg1", 2.244e6 / 8000, "paper: 2.244s/8000 frames")
    emit("table3/paper_fpga_alg3", 0.457e6 / 8000, "paper: 0.457s=17544fps,719MBps")

    # -- trajectory point: pre-PR ingest vs new executor at paper config ---
    pcfg = DenoiseConfig(
        num_groups=PAPER_G, frames_per_group=PAPER_N,
        height=PAPER_H, width=PAPER_W, backend="xla",
    )
    pgroups = list(PrismSource(pcfg).groups())
    _stream_alg3_f32(pcfg, pgroups)          # warm both paths
    _stream_prefetch(pcfg, pgroups)
    iters = 1 if quick else 2
    t_old = min(_stream_alg3_f32(pcfg, pgroups) for _ in range(iters))
    t_new = min(_stream_prefetch(pcfg, pgroups) for _ in range(iters))
    speedup = t_old / max(t_new, 1e-9)
    emit(
        "table3/paper_cfg_prefetch_vs_f32",
        t_new * 1e6 / (pcfg.num_groups * pcfg.frames_per_group),
        f"pre_pr_s={t_old:.3f};new_s={t_new:.3f};speedup={speedup:.2f}x",
    )
    bench_record(
        "streaming_prefetch_vs_presync",
        kind="speedup",
        config={
            "G": PAPER_G, "N": PAPER_N, "H": PAPER_H, "W": PAPER_W,
            "backend": "xla", "source": "pre-staged frames",
        },
        baseline="pre-PR ingest (host f32 convert, sync staging)",
        candidate="double-buffered u16 ingest (run_inline prefetch)",
        baseline_s=t_old,
        candidate_s=t_new,
        speedup=round(speedup, 3),
    )
