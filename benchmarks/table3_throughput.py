"""Paper Table 3: throughput under software trigger (max camera rate).

Streams the synthetic PRISM acquisition group-by-group through each
algorithm's streaming dataflow: Alg 3 folds into the running sum; Alg 1/2
stage difference frames into a tmpFrame buffer and reduce at the end.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, emit
from repro.core.streaming import StreamReport
from repro.data.prism import PrismSource
from repro.kernels import ops


def _stream_alg3(cfg, groups):
    t0 = time.perf_counter()
    state = ops.stream_init(cfg.frames_per_group, cfg.height, cfg.width)
    for gf in groups:
        state = ops.stream_step(
            state, jnp.asarray(gf.astype(np.float32)),
            num_groups=cfg.num_groups, offset=cfg.offset, backend="xla",
        )
    out = ops.stream_finalize(state, cfg.num_groups)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _stream_materialized(cfg, groups):
    """Alg 1/2 dataflow: store per-group diffs, reduce after the last."""
    t0 = time.perf_counter()
    p = cfg.pairs_per_group

    @jax.jit
    def diff(gf):
        pr = gf.reshape(p, 2, cfg.height, cfg.width)
        return pr[:, 1] - pr[:, 0] + cfg.offset

    tmp = jnp.zeros((cfg.num_groups, p, cfg.height, cfg.width), jnp.float32)
    for gi, gf in enumerate(groups):
        tmp = tmp.at[gi].set(diff(jnp.asarray(gf.astype(np.float32))))
    out = tmp.sum(0) / cfg.num_groups
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def run(quick: bool = True) -> None:
    cfg = bench_config(quick)
    src = PrismSource(cfg)
    groups = list(src.groups())
    frames = cfg.num_groups * cfg.frames_per_group
    mb = frames * cfg.frame_pixels * 2 / 1e6
    for name, fn in (
        ("no_burst(alg1-dataflow)", _stream_materialized),
        ("burst_rw(alg3-dataflow)", _stream_alg3),
    ):
        t = min(fn(cfg, groups) for _ in range(2))
        emit(
            f"table3/{name}",
            t * 1e6 / frames,
            f"fps={frames / t:.0f};MBps={mb / t:.1f}",
        )
    # paper hardware reference points
    emit("table3/paper_fpga_alg1", 2.244e6 / 8000, "paper: 2.244s/8000 frames")
    emit("table3/paper_fpga_alg3", 0.457e6 / 8000, "paper: 0.457s=17544fps,719MBps")
