"""Paper Table 6: per-frame latency stays flat as group count grows."""

from __future__ import annotations

from benchmarks.common import bench_config, emit
from repro.core.streaming import run_inline
from repro.data.prism import PrismSource


def run(quick: bool = True) -> None:
    per_frame = {}
    for g in (5, 8, 10):
        cfg = bench_config(quick, num_groups=g)
        groups = list(PrismSource(cfg).groups())  # pre-generate frames
        run_inline(cfg, iter(groups))             # warm the jit cache
        out, rep = run_inline(cfg, iter(groups))
        per_frame[g] = rep.elapsed_s * 1e6 / rep.frames
        emit(
            f"table6/groups_{g}",
            per_frame[g],
            f"frames={rep.frames};elapsed_s={rep.elapsed_s:.3f}",
        )
    spread = max(per_frame.values()) / max(min(per_frame.values()), 1e-9)
    emit("table6/latency_spread", spread, "max/min per-frame (paper: ~1.005)")
