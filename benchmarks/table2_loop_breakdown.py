"""Paper Table 2: loop-level latency breakdown.

Host analogue: time the three phases separately — subtraction only,
tmpFrame write (Alg 1/2's DRAM materialization), and read+average
(Alg 1/2's final-group reads) vs the fused running-sum pass (Alg 3).
Plus the paper's loop II table (pipelined II=1 for Alg 3 loops).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, emit, timeit


def run(quick: bool = True) -> None:
    cfg = bench_config(quick)
    rng = np.random.default_rng(0)
    frames = jnp.asarray(
        rng.integers(
            0, 4096, (cfg.num_groups, cfg.frames_per_group, cfg.height, cfg.width)
        ).astype(np.float32)
    )
    g, n = cfg.num_groups, cfg.frames_per_group
    pairs = frames.reshape(g, n // 2, 2, cfg.height, cfg.width)

    @jax.jit
    def subtract_only(p):
        return p[:, :, 1] - p[:, :, 0] + cfg.offset

    @jax.jit
    def write_tmp(p):  # materialized difference frames (Alg 1/2 writes)
        return jax.lax.optimization_barrier(p[:, :, 1] - p[:, :, 0] + cfg.offset)

    tmp = write_tmp(pairs)

    @jax.jit
    def read_average(t):  # final-group reads (Alg 1/2)
        return t.sum(0) / g

    @jax.jit
    def fused(p):  # Alg 3: one pass, running sum
        def body(s, grp):
            return s + (grp[:, 1] - grp[:, 0] + cfg.offset), None

        init = jnp.zeros((n // 2, cfg.height, cfg.width), jnp.float32)
        total, _ = jax.lax.scan(body, init, p)
        return total / g

    total_frames = g * n
    for name, fn, arg in (
        ("PixSubLoop", subtract_only, pairs),
        ("WriteToDRAMLoop", write_tmp, pairs),
        ("ReadFromDRAMLoop", read_average, tmp),
        ("FusedRunningSum(alg3)", fused, pairs),
    ):
        t = timeit(fn, arg)
        emit(f"table2/{name}", t * 1e6 / total_frames, f"total_s={t:.4f}")
    # paper: achieved initiation intervals (Table 2) — II=1 only for alg3 loops
    emit("table2/II/alg1_PixSubAvgLoop", 7, "paper achieved II, not pipelined to 1")
    emit("table2/II/alg3_all_loops", 1, "paper achieved II (pipelined)")
