"""Benchmark harness: one module per paper table. Prints
``name,us_per_call,derived`` CSV. ``--full`` uses paper-scale N=1000."""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    fig8_denoise_snr,
    roofline_report,
    table1_kernel_latency,
    table2_loop_breakdown,
    table3_throughput,
    table4_led_trigger,
    table5_multibank,
    table6_group_sweep,
    table7_cpu_baseline,
    table8_buffered_vs_inline,
    table9_ring_depth,
    table10_filter_zoo,
)

MODULES = [
    ("table1", table1_kernel_latency),
    ("table2", table2_loop_breakdown),
    ("table3", table3_throughput),
    ("table4", table4_led_trigger),
    ("table5", table5_multibank),
    ("table6", table6_group_sweep),
    ("table7", table7_cpu_baseline),
    ("table8-10", table8_buffered_vs_inline),
    ("table9", table9_ring_depth),
    ("table10-zoo", table10_filter_zoo),
    ("fig8", fig8_denoise_snr),
    ("roofline", roofline_report),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale N=1000")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod.run(quick=not args.full)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},-1,EXCEPTION")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
