"""Benchmark harness: one module per paper table. Prints
``name,us_per_call,derived`` CSV. ``--full`` uses paper-scale N=1000;
``--list`` prints the registry; an unknown ``--only`` raises a
``ValueError`` listing the valid module names (the repo's
dispatch-validation convention)."""

from __future__ import annotations

import argparse
import sys
import time
import traceback
from typing import Sequence

from benchmarks import (
    fig8_denoise_snr,
    roofline_report,
    table1_kernel_latency,
    table2_loop_breakdown,
    table3_throughput,
    table4_led_trigger,
    table5_multibank,
    table6_group_sweep,
    table7_cpu_baseline,
    table8_buffered_vs_inline,
    table9_ring_depth,
    table10_filter_zoo,
    table11_multitenant,
    table12_autotune,
    table13_bandwidth,
    table14_fleet,
    table15_observability,
    table16_slo,
    table17_autoscale,
)

MODULES = [
    ("table1", table1_kernel_latency),
    ("table2", table2_loop_breakdown),
    ("table3", table3_throughput),
    ("table4", table4_led_trigger),
    ("table5", table5_multibank),
    ("table6", table6_group_sweep),
    ("table7", table7_cpu_baseline),
    ("table8-10", table8_buffered_vs_inline),
    ("table9", table9_ring_depth),
    ("table10-zoo", table10_filter_zoo),
    ("table11-multitenant", table11_multitenant),
    ("table12-autotune", table12_autotune),
    ("table13-bandwidth", table13_bandwidth),
    ("table14-fleet", table14_fleet),
    ("table15-observability", table15_observability),
    ("table16-slo", table16_slo),
    ("table17-autoscale", table17_autoscale),
    ("fig8", fig8_denoise_snr),
    ("roofline", roofline_report),
]


def select(only: str | None) -> list:
    """Modules whose registry name contains ``only`` (all when None).

    Raises ``ValueError`` listing the valid names when nothing matches —
    same contract as the ``ops``/filter dispatch errors, so a typo'd
    ``--only`` fails loudly instead of silently running nothing.
    """
    if only is None:
        return MODULES
    picked = [(name, mod) for name, mod in MODULES if only in name]
    if not picked:
        names = tuple(name for name, _ in MODULES)
        raise ValueError(
            f"--only must match one of {names}, got {only!r}"
        )
    return picked


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale N=1000")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--list",
        action="store_true",
        help="print the registered module names and exit",
    )
    args = ap.parse_args(argv)
    if args.list:
        for name, mod in MODULES:
            doc = (mod.__doc__ or "").strip()
            print(name, "-", doc.splitlines()[0] if doc else "(no description)")
        return
    picked = select(args.only)
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in picked:
        t0 = time.time()
        try:
            mod.run(quick=not args.full)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},-1,EXCEPTION")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
