"""Table 13 (framework extension): the bandwidth tier's bytes-vs-quality
ledger.

The paper's whole argument is bandwidth engineering: the denoise kernel is
memory-bound, so moving fewer bytes per frame is the remaining lever once
geometry and overlap are tuned (tables 9/12). This table sweeps the
``stream_dtype`` wire formats (u16 baseline, u8 quantized, p12 packed)
across filters and backends and records, per cell:

* **wire bytes per frame** — ``config.bytes_per_frame``, the container
  bytes the acquisition stream actually moves per frame (the quantity
  ``StreamReport.bytes_in`` accounts and the paper's DRAM argument is
  about): 2x smaller for u8, 1.33x for p12, by construction of the wire.
* **compiler-counted step bytes** — total ``bytes accessed`` from
  ``cost_analysis()`` of the XLA lowering of the filter's real ingest
  step at the sweep shape (accumulator traffic included — the honest
  whole-step denominator; per-operand attribution is deliberately not
  used: XLA reorders and fuses operands). Taken from
  the XLA lowering for every sweep backend: off-TPU the Pallas path runs
  in interpret mode, whose cost attribution is not meaningful, and the
  wire math is identical either way. On CPU this count is honest about
  p12: the packed format trades wire bytes for unpack reads, so its
  whole-step count can *rise* off-TPU while the wire shrinks.
* **measured throughput** — full-stream frames/s for the narrow format vs
  the u16 baseline, timed with table12's paired, order-balanced
  median-of-ratios discipline (each format streams its *own* wire-format
  staged chunks).
* **model roofline fraction** — the analytic HBM traffic of the format
  (``latency_model.hbm_traffic_bytes`` at its wire bytes/pixel) against
  the v5e 819 GB/s bound, as the fraction the measured pass achieves.
* **SNR delta** — full-pipeline SNR against the noise-free truth for the
  narrow format minus the u16 baseline (p12 is exact: delta is 0 by
  construction; u8 pays its quantization floor here, on the record).

Points land in ``BENCH_denoise.json`` as the ``bandwidth`` trajectory
(``kind="bandwidth"``). Run directly for the CI smoke cycle::

    python -m benchmarks.table13_bandwidth --smoke --assert-u8-reduction

``--assert-u8-reduction`` exits non-zero unless, on every swept filter,
the u8 wire bytes shrink >= 1.5x vs u16 AND the compiler-counted step
bytes strictly shrink (load-independent: both are static counts).
"""

from __future__ import annotations

import argparse
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    PAPER_G,
    PAPER_H,
    PAPER_N,
    PAPER_W,
    bench_config,
    bench_record,
    emit,
    stream_pass_s,
)
from repro.core import latency_model as lm
from repro.core.denoise import StreamingDenoiser
from repro.data.prism import PrismSource, snr_db
from repro.kernels import ops, quant

FILTER_SWEEP = ("pair_average", "ema_variance")
NARROW = ("u8", "p12")
_HBM_GBPS = 819.0  # v5e bound, same constant as roofline_report
_ITERS = 6

#: filter -> ops entry used for its per-group ingest step
_COST_OPS = {
    "pair_average": "stream",
    "spatial_box": "stream",
    "temporal_median": "median_insert",
    "ema_variance": "ema",
}


def _wire_chunk(cfg, seed=0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    mono12 = rng.integers(0, 4096, (cfg.frames_per_group, cfg.height, cfg.width))
    return jnp.asarray(quant.encode(mono12.astype(np.uint16), cfg.stream_dtype))


def _step_cost_bytes(cfg) -> float:
    """Compiler-counted total bytes per frame for one ingest step.

    Lowers the filter's real jitted ingest entry point with ``backend=
    "xla"`` at the config's shape and wire format and reads the compiled
    ``cost_analysis()`` total ``bytes accessed``.
    """
    family = _COST_OPS[cfg.filter_name]
    n, h, w = cfg.frames_per_group, cfg.height, cfg.width
    acc = jnp.dtype(cfg.accum_dtype)
    sd = cfg.stream_dtype
    chunk = _wire_chunk(cfg)
    kw = dict(offset=cfg.offset, backend="xla", stream_dtype=sd)
    if family == "stream":
        lowered = ops.stream_step.lower(
            ops.stream_init(n, h, w, acc), chunk,
            num_groups=cfg.num_groups, **kw,
        )
    elif family == "median_insert":
        window = jnp.zeros((cfg.median_window, n // 2, h, w), acc)
        lowered = ops.median_window_insert.lower(window, chunk, slot=0, **kw)
    else:  # ema
        lowered = ops.ema_welford_step.lower(
            jnp.zeros((n // 2, h, w), acc),
            jnp.zeros((h, w), acc),
            jnp.zeros((h, w), acc),
            chunk,
            alpha=cfg.ema_alpha, prior_count=0, **kw,
        )
    cost = lowered.compile().cost_analysis()
    if isinstance(cost, list):  # some jax versions wrap per-device
        cost = cost[0]
    return float(cost.get("bytes accessed", 0.0)) / n


def _staged(cfg, seed=7):
    groups = [
        jax.device_put(np.asarray(c))
        for c in PrismSource(cfg, seed=seed).groups()
    ]
    jax.block_until_ready(groups)
    return groups


def _paired_ratio(den_a, groups_a, den_b, groups_b, iters=_ITERS):
    """(a_s, b_s, a/b speedup): table12's interleaved paired-median
    discipline, generalized to per-denoiser staged chunks (each wire
    format streams its own containers)."""
    stream_pass_s(den_a, groups_a)  # warm both jits
    stream_pass_s(den_b, groups_b)
    a_times, b_times = [], []
    for i in range(iters):
        if i % 2:
            b = stream_pass_s(den_b, groups_b)
            a = stream_pass_s(den_a, groups_a)
        else:
            a = stream_pass_s(den_a, groups_a)
            b = stream_pass_s(den_b, groups_b)
        a_times.append(a)
        b_times.append(b)
    ratios = [x / max(y, 1e-9) for x, y in zip(a_times, b_times)]
    return (
        float(np.median(a_times)),
        float(np.median(b_times)),
        float(np.median(ratios)),
    )


def _snr(cfg, seed=7) -> float:
    src = PrismSource(cfg, seed=seed)
    den = StreamingDenoiser(cfg)
    state = den.init()
    for k, g in enumerate(src.groups()):
        state = den.ingest(state, jnp.asarray(g), step=k)
    out = np.asarray(den.finalize(state))
    return snr_db(out, src.true_signal())


def _roofline_frac(cfg, pass_s: float) -> float:
    traffic = lm.hbm_traffic_bytes(
        "alg3",
        groups=cfg.num_groups,
        frames_per_group=cfg.frames_per_group,
        height=cfg.height,
        width=cfg.width,
        in_bytes=cfg.wire_pixel_bytes,
    )["streaming_total"]
    return (traffic / (_HBM_GBPS * 1e9)) / max(pass_s, 1e-12)


def _sweep_shapes(quick: bool, smoke: bool, backend: str):
    on_tpu = jax.default_backend() == "tpu"
    if smoke:
        return [(3, 40, 16, 64)]
    if backend == "pallas" and not on_tpu:
        return [(4, 60, 40, 128)]
    if quick:
        return [(4, 200, PAPER_H, PAPER_W)]
    return [(PAPER_G, PAPER_N, PAPER_H, PAPER_W)]


def run(
    quick: bool = True,
    *,
    smoke: bool = False,
    assert_u8_reduction: bool = False,
) -> None:
    short = []
    backends = ("xla",) if smoke else ("xla", "pallas")
    filters = FILTER_SWEEP
    for backend in backends:
        for g, n, h, w in _sweep_shapes(quick, smoke, backend):
            for name in filters:
                common = dict(
                    num_groups=g, frames_per_group=n, height=h, width=w,
                    backend=backend, filter_name=name,
                )
                cfg16 = bench_config(quick, **common)
                step16 = _step_cost_bytes(cfg16)
                groups16 = _staged(cfg16)
                den16 = StreamingDenoiser(cfg16)
                snr16 = _snr(cfg16)
                frames = g * n
                for sd in NARROW:
                    cfg = bench_config(quick, **common, stream_dtype=sd)
                    step_b = _step_cost_bytes(cfg)
                    base_s, narrow_s, speedup = _paired_ratio(
                        den16, groups16, StreamingDenoiser(cfg), _staged(cfg)
                    )
                    snr = _snr(cfg)
                    wire, wire16 = cfg.bytes_per_frame, cfg16.bytes_per_frame
                    wire_ratio = wire16 / max(wire, 1)
                    if sd == "u8" and (wire_ratio < 1.5 or step_b >= step16):
                        short.append(
                            f"{name}/{backend}: wire {wire_ratio:.2f}x, "
                            f"step {step16:.0f}->{step_b:.0f} B/frame"
                        )
                    tag = f"table13/{name}/{backend}/{sd}/N{n}"
                    emit(
                        tag,
                        narrow_s * 1e6 / frames,
                        f"u16_us={base_s * 1e6 / frames:.1f};"
                        f"speedup={speedup:.2f}x;"
                        f"wire_Bpf={wire}vs{wire16}({wire_ratio:.2f}x);"
                        f"step_Bpf={step_b:.0f}vs{step16:.0f};"
                        f"snr_delta_db={snr - snr16:+.2f};"
                        f"roofline_frac={_roofline_frac(cfg, narrow_s):.5f}"
                        f"vs{_roofline_frac(cfg16, base_s):.5f}",
                    )
                    bench_record(
                        "bandwidth",
                        kind="bandwidth",
                        config={
                            "G": g, "N": n, "H": h, "W": w,
                            "backend": backend, "filter": name,
                        },
                        baseline="stream_dtype=u16 (mono12-in-u16 wire)",
                        candidate=f"stream_dtype={sd}",
                        wire_bytes_per_frame=wire,
                        wire_bytes_per_frame_u16=wire16,
                        wire_reduction=round(wire_ratio, 3),
                        step_bytes_per_frame=round(step_b, 1),
                        step_bytes_per_frame_u16=round(step16, 1),
                        step_reduction=round(step16 / max(step_b, 1e-9), 3),
                        baseline_s=round(base_s, 5),
                        candidate_s=round(narrow_s, 5),
                        speedup=round(speedup, 3),
                        fps=round(frames / max(narrow_s, 1e-9), 1),
                        roofline_frac=round(_roofline_frac(cfg, narrow_s), 6),
                        roofline_frac_u16=round(
                            _roofline_frac(cfg16, base_s), 6
                        ),
                        snr_db=round(snr, 3),
                        snr_delta_db=round(snr - snr16, 3),
                    )
    if assert_u8_reduction and short:
        raise SystemExit(
            "expected every swept filter to move >=1.5x fewer u8 wire "
            "bytes AND fewer compiler-counted step bytes than u16, but "
            f"these fell short: {short}"
        )


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale N=1000")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny shape, xla only: the CI bytes-reduction check",
    )
    ap.add_argument(
        "--assert-u8-reduction", action="store_true",
        help="fail unless u8 ingest bytes shrink >=1.5x vs u16 everywhere",
    )
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(
        quick=not args.full,
        smoke=args.smoke,
        assert_u8_reduction=args.assert_u8_reduction,
    )


if __name__ == "__main__":
    main()
