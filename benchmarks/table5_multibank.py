"""Paper Table 5: multi-bank scaling (1 vs 2 banks on separate devices).

The paper shows flat latency from 1 bank/1 FPGA to 2 banks/2 FPGAs. The
TPU analogue shards the bank axis over devices with shard_map (zero
cross-bank collectives). Runs in a subprocess with 2 host devices.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import time, numpy as np, jax, jax.numpy as jnp
    from repro.core.banks import banked_subtract_average, make_bank_mesh
    from repro.core.denoise import DenoiseConfig

    N = int(os.environ.get("BANK_N", "200"))
    cfg = DenoiseConfig(num_groups=8, frames_per_group=N, height=80, width=256)
    rng = np.random.default_rng(0)

    def bench(banks):
        mesh = make_bank_mesh(banks)
        x = jnp.asarray(rng.integers(0, 4096,
            (banks, cfg.num_groups, cfg.frames_per_group, 80, 256)
        ).astype(np.float32))
        out = banked_subtract_average(x, mesh, config=cfg)  # compile
        jax.block_until_ready(out)
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(banked_subtract_average(x, mesh, config=cfg))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t1 = bench(1)
    t2 = bench(2)
    print(f"BANKS,{t1:.4f},{t2:.4f},{t2 / t1:.3f}")
""")


def run(quick: bool = True) -> None:
    env = dict(os.environ, BANK_N="100" if quick else "400")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _CODE], capture_output=True, text=True,
        env=env, timeout=900,
    )
    line = [l for l in out.stdout.splitlines() if l.startswith("BANKS")]
    if not line:
        emit("table5/multibank", -1, f"FAILED:{out.stderr[-200:]}")
        return
    _, t1, t2, ratio = line[0].split(",")
    emit("table5/one_bank", float(t1) * 1e6, "elapsed_us_total")
    emit(
        "table5/two_banks",
        float(t2) * 1e6,
        f"scaling_ratio={ratio} (paper: 1.00 flat; host devices share ONE "
        "physical core here, so ~2x is the serialization ceiling — the "
        "shard_map program has zero cross-bank collectives, verified in "
        "tests/test_banks.py)",
    )
