"""Paper Table 5: multi-bank scaling (1 vs 2 banks on separate devices).

The paper shows flat latency from 1 bank/1 FPGA to 2 banks/2 FPGAs. The
TPU analogue shards the bank axis over devices with shard_map (zero
cross-bank collectives). Runs in a subprocess with 2 host devices.

This table also measures old-vs-new for the bank pipeline itself at the
paper's default config (G=8, N=1000, 80×256): the *reference* path (what
``banked_subtract_average`` ran before — host f32 staging + a per-group
``ref_stream_step`` scan per bank) against the *fused* path it dispatches
now (u16 straight to device, subtract fused into the group reduction, one
program for all banks). The ratio is recorded to BENCH_denoise.json.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import PAPER_G, PAPER_H, PAPER_N, PAPER_W, bench_record, emit

_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import functools, time, numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.banks import banked_subtract_average, make_bank_mesh
    from repro.core.denoise import DenoiseConfig
    from repro.jax_compat import pcast_varying, shard_map
    from repro.kernels.ref import ref_stream_step, ref_stream_finalize

    N = int(os.environ.get("BANK_N", "200"))
    FULL_N = int(os.environ.get("BANK_FULL_N", "1000"))
    cfg = DenoiseConfig(num_groups=8, frames_per_group=N, height=80, width=256)
    rng = np.random.default_rng(0)

    def reference_banked(frames_u16, mesh, config):
        # the pre-PR path: host f32 convert, then a per-group scan of the
        # reference step per bank inside shard_map
        x = jnp.asarray(frames_u16.astype(np.float32))
        spec = P("bank", None, None, None, None)

        @functools.partial(shard_map, mesh=mesh, in_specs=spec,
                           out_specs=P("bank", None, None, None))
        def _per_bank(local):
            def one(f):
                g = f.shape[0]
                def body(s, grp):
                    return ref_stream_step(s, grp, offset=config.offset,
                        variant=config.variant, num_groups=g), None
                init = pcast_varying(
                    jnp.zeros((f.shape[1] // 2, f.shape[2], f.shape[3]),
                              jnp.float32), ("bank",))
                total, _ = jax.lax.scan(body, init, f)
                return ref_stream_finalize(total, g, variant=config.variant)
            return jax.vmap(one)(local)

        return _per_bank(jax.device_put(x, NamedSharding(mesh, spec)))

    def fused_banked(frames_u16, mesh, config):
        # the new path: u16 straight to device, fused ops dispatch
        return banked_subtract_average(jnp.asarray(frames_u16), mesh,
                                       config=config)

    def bench(fn, x, mesh, config, iters=3):
        jax.block_until_ready(fn(x, mesh, config))  # compile
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x, mesh, config))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    # -- scaling: 1 vs 2 banks, fused path --------------------------------
    def scaling(banks):
        mesh = make_bank_mesh(banks)
        x = rng.integers(0, 4096,
            (banks, cfg.num_groups, cfg.frames_per_group, 80, 256)
        ).astype(np.uint16)
        return bench(fused_banked, x, mesh, cfg)

    t1 = scaling(1)
    t2 = scaling(2)
    print(f"BANKS,{t1:.4f},{t2:.4f},{t2 / t1:.3f}")

    # -- old vs new at the paper default config (single bank) -------------
    pcfg = DenoiseConfig(num_groups=8, frames_per_group=FULL_N,
                         height=80, width=256)
    mesh1 = make_bank_mesh(1)
    xp = rng.integers(0, 4096,
        (1, pcfg.num_groups, pcfg.frames_per_group, 80, 256)).astype(np.uint16)
    t_ref = bench(reference_banked, xp, mesh1, pcfg)
    t_fused = bench(fused_banked, xp, mesh1, pcfg)
    # parity while we're here
    a = np.asarray(reference_banked(xp, mesh1, pcfg))
    b = np.asarray(fused_banked(xp, mesh1, pcfg))
    np.testing.assert_allclose(a, b, rtol=1e-5)
    print(f"FUSED,{t_ref:.4f},{t_fused:.4f},{t_ref / t_fused:.3f}")
""")


def run(quick: bool = True) -> None:
    # BANK_FULL_N stays at paper scale even in quick mode: the recorded
    # trajectory point must be at the paper default config (~25 s here).
    env = dict(
        os.environ, BANK_N="100" if quick else "400", BANK_FULL_N=str(PAPER_N)
    )
    env.pop("XLA_FLAGS", None)
    try:
        out = subprocess.run(
            [sys.executable, "-c", _CODE], capture_output=True, text=True,
            env=env, timeout=1800,
        )
    except subprocess.TimeoutExpired as e:
        tail = (e.stderr or b"")[-200:] if isinstance(e.stderr, bytes) else ""
        emit("table5/multibank", -1, f"TIMEOUT after {e.timeout}s {tail}")
        return
    lines = {
        l.split(",")[0]: l.split(",")
        for l in out.stdout.splitlines()
        if l.startswith(("BANKS", "FUSED"))
    }
    if "BANKS" not in lines or "FUSED" not in lines:
        emit("table5/multibank", -1, f"FAILED:{out.stderr[-200:]}")
        return
    _, t1, t2, ratio = lines["BANKS"]
    emit("table5/one_bank", float(t1) * 1e6, "elapsed_us_total")
    emit(
        "table5/two_banks",
        float(t2) * 1e6,
        f"scaling_ratio={ratio} (paper: 1.00 flat; host devices share ONE "
        "physical core here, so ~2x is the serialization ceiling — the "
        "shard_map program has zero cross-bank collectives, verified in "
        "tests/test_banks.py)",
    )
    _, t_ref, t_fused, speedup = lines["FUSED"]
    emit(
        "table5/fused_vs_reference",
        float(t_fused) * 1e6,
        f"reference_us={float(t_ref) * 1e6:.1f};speedup={speedup}x "
        "(paper default G=8,N=1000,80x256, single bank)",
    )
    bench_record(
        "multibank_fused_vs_reference",
        kind="speedup",
        config={
            "G": PAPER_G,
            "N": PAPER_N,
            "H": PAPER_H,
            "W": PAPER_W,
            "banks": 1,
            "backend": "xla",
        },
        baseline="reference (host f32 + per-group ref_stream_step scan)",
        candidate="fused (u16 in, subtract fused into group reduction)",
        baseline_s=float(t_ref),
        candidate_s=float(t_fused),
        speedup=float(speedup),
    )
