"""Table 12 (framework extension): measured autotuner vs heuristic plans.

The paper's design-space exploration picks burst lengths and buffer
geometry so the kernel rides under the inter-frame interval; this table
runs the jax_pallas analogue (``repro.tune``) and records what measuring
buys over the shared budget heuristic:

* **kernel points** — per (filter, backend, shape): full-stream ingest
  throughput under ``tile_plan="heuristic"`` vs ``tile_plan="auto"``
  (tuned block geometry), interleaved min-of-iters. The tuner's candidate
  set always contains the heuristic, so tuned >= heuristic up to
  run-to-run noise — the acceptance signal for the tuning layer.
* **executor points** — ring-depth knob: the same bursty device-resident
  replay as table9, config-default ping-pong (``num_slots=2``) vs the
  plan's measured depth.

Points land in ``BENCH_denoise.json`` as the ``autotune`` trajectory
(``kind="kernel"`` / ``kind="executor"``); each carries the resolved
plan string and its provenance (``tuned`` vs ``cache``).

Run directly for the CI smoke cycle (search -> cache write -> cache hit)::

    REPRO_TUNE_CACHE_PATH=/tmp/plans.json \\
        python -m benchmarks.table12_autotune --smoke
    python -m benchmarks.table12_autotune --smoke --expect-cache-hit

``--smoke`` shrinks the sweep to one filter per backend at a tiny shape;
``--expect-cache-hit`` exits non-zero if any plan had to re-measure
(i.e. the persistent cache did not serve it).
"""

from __future__ import annotations

import argparse
import time
from typing import Sequence

import jax
import numpy as np

from benchmarks.common import (
    PAPER_G,
    PAPER_H,
    PAPER_N,
    PAPER_W,
    bench_config,
    bench_record,
    emit,
    stream_pass_s,
)
from benchmarks.table9_ring_depth import BURST_COMPUTE_MULT, bursty
from repro import tune
from repro.core.denoise import StreamingDenoiser
from repro.core.streaming import run_pipelined
from repro.data.prism import PrismSource

FILTER_SWEEP = ("pair_average", "temporal_median", "ema_variance", "spatial_box")
_ITERS = 6  # even: half the pairs run heuristic-first, half tuned-first,
# so a "first run in the pair is slower" effect cancels in the median


def _staged_groups(cfg, seed=5):
    groups = [jax.device_put(np.asarray(c)) for c in PrismSource(cfg, seed=seed).groups()]
    jax.block_until_ready(groups)
    return groups


def _min_interleaved(d_heur, d_tuned, groups, iters=_ITERS):
    """(heuristic_s, tuned_s, speedup) with a paired-ratio speedup.

    Host load on a small shared container drifts on second scales
    (A/A ratios swing ~±30%), so independent minima are not comparable.
    Each iteration times the two plans back to back and contributes one
    heur/tuned *ratio*; the recorded speedup is the median ratio (common-
    mode drift cancels within a pair), alongside median absolute times.
    """
    heur, tuned = [], []
    stream_pass_s(d_heur, groups)  # warm both jits
    stream_pass_s(d_tuned, groups)
    for i in range(iters):
        if i % 2:  # alternate order inside the pair: no systematic bias
            t = stream_pass_s(d_tuned, groups)
            h = stream_pass_s(d_heur, groups)
        else:
            h = stream_pass_s(d_heur, groups)
            t = stream_pass_s(d_tuned, groups)
        heur.append(h)
        tuned.append(t)
    ratios = [h / max(t, 1e-9) for h, t in zip(heur, tuned)]
    return float(np.median(heur)), float(np.median(tuned)), float(np.median(ratios))


def _matches_heuristic(plan, cfg) -> bool:
    """True when every family's tuned geometry equals the budget-model
    pick — the residual A/B ratio is then pure measurement noise (the two
    plans lower to the same kernel)."""
    from repro.kernels import quant
    from repro.tune import budget
    from repro.tune.autotune import _in_dtype, _stream_dtype, filter_families

    sd = _stream_dtype(cfg)
    p = cfg.frames_per_group // 2
    for fam, window in filter_families(cfg):
        args = plan.tile_args(fam)
        if args["row_tile"] is None:
            continue
        th, tp = budget.resolve_tiles(
            fam, p, cfg.height, cfg.width, in_dtype=_in_dtype(cfg),
            acc_dtype=cfg.accum_dtype, window=window,
            in_pixel_bytes=None if sd == "u16" else quant.wire_pixel_bytes(sd),
        )
        if (args["row_tile"], args["pair_tile"]) != (th, tp):
            return False
        # a non-default placement scheme changes the lowering too
        if args.get("placement") not in (None, budget.placement_schemes(fam)[0]):
            return False
    return True


def _sweep_shapes(quick: bool, smoke: bool, backend: str):
    """(G, N, H, W) per backend: pallas runs in interpret mode off-TPU, so
    its CPU shapes stay small enough to keep the quick sweep fast."""
    on_tpu = jax.default_backend() == "tpu"
    if smoke:
        return [(3, 40, 16, 64)]
    if backend == "pallas" and not on_tpu:
        return [(4, 60, 40, 128)]
    if quick:
        return [(4, 200, PAPER_H, PAPER_W)]
    return [(PAPER_G, PAPER_N, PAPER_H, PAPER_W)]


def run(quick: bool = True, *, smoke: bool = False, expect_cache_hit: bool = False) -> None:
    backends = ["xla", "pallas"]
    filters = ("pair_average",) if smoke else FILTER_SWEEP
    missed_cache = []
    for backend in backends:
        for g, n, h, w in _sweep_shapes(quick, smoke, backend):
            for name in filters:
                common = dict(
                    num_groups=g, frames_per_group=n, height=h, width=w,
                    backend=backend, filter_name=name,
                )
                cfg_h = bench_config(quick, **common, tile_plan="heuristic")
                cfg_t = bench_config(quick, **common, tile_plan="auto")
                groups = _staged_groups(cfg_h)
                t0 = time.perf_counter()
                den_t = StreamingDenoiser(cfg_t)  # resolves (tunes) the plan
                tune_s = time.perf_counter() - t0
                plan = den_t.plan
                if plan.source != "cache":
                    missed_cache.append(f"{name}/{backend}/{g}x{n}x{h}x{w}")
                den_h = StreamingDenoiser(cfg_h)
                heur_s, tuned_s, speedup = _min_interleaved(den_h, den_t, groups)
                frames = g * n
                same = _matches_heuristic(plan, cfg_t)
                tag = f"table12/{name}/{backend}/N{n}"
                emit(
                    tag,
                    tuned_s * 1e6 / frames,
                    f"heuristic_us={heur_s * 1e6 / frames:.1f};"
                    f"speedup={speedup:.2f}x;plan_source={plan.source};"
                    f"plan_matches_heuristic={same};tune_s={tune_s:.2f}",
                )
                bench_record(
                    "autotune",
                    kind="kernel",
                    config={
                        "G": g, "N": n, "H": h, "W": w,
                        "backend": backend, "filter": name,
                    },
                    baseline="tile_plan=heuristic (shared budget model)",
                    candidate="tile_plan=auto (measured plan)",
                    baseline_s=round(heur_s, 5),
                    candidate_s=round(tuned_s, 5),
                    speedup=round(speedup, 3),
                    plan=plan.describe(),
                    plan_source=plan.source,
                    plan_matches_heuristic=same,
                    tune_s=round(tune_s, 3),
                )

        # executor knob: config-default ping-pong vs the plan's measured
        # ring depth under the table9 bursty replay (xla: the knob is
        # backend-independent and the xla step is the fast one here)
        if backend != "xla":
            continue
        g, n, h, w = _sweep_shapes(quick, smoke, backend)[0]
        cfg_t = bench_config(
            quick, num_groups=max(g, 6), frames_per_group=n, height=h,
            width=w, backend=backend, tile_plan="auto",
        )
        cfg_h = bench_config(
            quick, num_groups=max(g, 6), frames_per_group=n, height=h,
            width=w, backend=backend, tile_plan="heuristic",
        )
        plan = tune.resolve_plan(cfg_t)
        chunks = _staged_groups(cfg_h)
        run_pipelined(cfg_h, iter(chunks[:2]))  # warm
        ratios, h_times, t_times = [], [], []
        for i in range(4):  # paired rounds, burst recalibrated, order balanced
            t0 = time.perf_counter()
            run_pipelined(cfg_h, iter(chunks), num_slots=1)
            burst_s = max(
                BURST_COMPUTE_MULT * (time.perf_counter() - t0) / len(chunks),
                0.002,
            )
            if i % 2:
                _, rep_t = run_pipelined(cfg_t, bursty(chunks, burst_s, every=3))
                _, rep_h = run_pipelined(cfg_h, bursty(chunks, burst_s, every=3))
            else:
                _, rep_h = run_pipelined(cfg_h, bursty(chunks, burst_s, every=3))
                _, rep_t = run_pipelined(cfg_t, bursty(chunks, burst_s, every=3))
            h_times.append(rep_h.elapsed_s)
            t_times.append(rep_t.elapsed_s)
            ratios.append(rep_h.elapsed_s / max(rep_t.elapsed_s, 1e-9))
        ratios.sort()
        speedup = (ratios[1] + ratios[2]) / 2  # median of 4
        emit(
            f"table12/exec/{backend}/N{n}",
            rep_t.elapsed_s * 1e6 / rep_t.frames,
            f"slots={rep_t.num_slots}vs{rep_h.num_slots};"
            f"speedup={speedup:.2f}x;overlap={rep_t.overlap_frac:.2f}",
        )
        bench_record(
            "autotune",
            kind="executor",
            config={
                "G": cfg_h.num_groups, "N": n, "H": h, "W": w,
                "backend": backend, "filter": "pair_average",
                "burst_compute_mult": BURST_COMPUTE_MULT,
            },
            baseline=f"config default num_slots={rep_h.num_slots} (ping-pong)",
            candidate=f"plan num_slots={rep_t.num_slots} (measured)",
            baseline_s=round(float(np.median(h_times)), 4),
            candidate_s=round(float(np.median(t_times)), 4),
            speedup=round(speedup, 3),
            plan=plan.describe(),
            plan_source=plan.source,
        )

    if expect_cache_hit and missed_cache:
        raise SystemExit(
            "expected every plan to come from the persistent cache, but "
            f"these re-measured: {missed_cache}"
        )


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale N=1000")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny search space: exercise search, cache write, cache hit",
    )
    ap.add_argument(
        "--expect-cache-hit", action="store_true",
        help="fail unless every plan resolution was a cache hit",
    )
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(
        quick=not args.full,
        smoke=args.smoke,
        expect_cache_hit=args.expect_cache_hit,
    )


if __name__ == "__main__":
    main()
