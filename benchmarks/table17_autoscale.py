"""Table 17 (framework extension): elastic autoscaling under overload.

Three cells over the serve tier's elastic pool (``repro.serve.autoscale``
+ ``repro.serve.loadgen``), all driven by a ``FakeClock`` — every
latency below is *virtual* seconds, so the numbers are exact and
deterministic run after run (zero wall-clock sleeps in any load path):

* **scaleup** — a flash-crowd arrival trace (steady base Poisson load
  plus a burst window, seeded loadgen) replayed against a fleet that
  starts at one executor. Admission rejections burn the
  ``admission_pressure`` SLO, the autoscaler reacts by raising the pool
  target and eager-spawning executors, and the breach clears once
  capacity lands. Records **scale-up reaction time**: virtual seconds
  from the first rejected admission to the first ``scale-up`` timeline
  mark (detection latency included). ``--assert-scaleup`` requires the
  full chain — ``slo_breach`` → ``fleet.scale_up`` → ``slo_recovered``
  — to survive a validated Chrome-trace export.
* **sustained** — max sessions the elastic pool sustains at the fixed
  admission SLO: sessions join one at a time (a rejection pumps one
  autoscaler tick, then retries once — the backoff rung in miniature)
  until the pool is at its ceiling and admission refuses anyway.
* **ladder** — a capacity-capped fleet (one executor, nowhere to grow)
  walks the graceful-degradation ladder under sustained overload:
  backoff → in-place ring downshift (``degrade`` instants) → shedding
  the lowest-priority session, then descends rung by rung once the
  breach clears (``restore`` instants) — after which the surviving
  lossless session's output is asserted **bit-identical** to the serial
  single-stream oracle. Records the Jain fairness index over groups
  served per session (shed sessions keep what they folded).

Run directly for the CI smoke cycle::

    python -m benchmarks.table17_autoscale --smoke --assert-scaleup
"""

from __future__ import annotations

import argparse
import threading
import time
from typing import Sequence

import numpy as np

from benchmarks.common import bench_config, bench_record, emit
from repro import obs
from repro.core.denoise import StreamingDenoiser
from repro.data.prism import PrismSource
from repro.serve import (
    AdmissionError,
    Autoscaler,
    FakeClock,
    FleetScheduler,
    Session,
    TenantProfile,
    admission_pressure_slo,
    build_trace,
    flash_crowd_schedule,
    replay_trace,
)

WAIT_S = 300          # bound on real event waits (never reached when healthy)
WINDOW_S = 2.0        # admission-SLO evaluation window (virtual seconds)
REJECT_BUDGET = 0.25  # allowed rejected/attempts fraction
SEED = 17


class _Gate:
    """Source yielding ``preload`` chunks immediately, the rest only
    after :meth:`release` — keeps sessions deterministically in flight
    so admission decisions depend on counts, never thread timing."""

    def __init__(self, chunks, preload: int = 0):
        self.chunks = list(chunks)
        self.preload = preload
        self.open = threading.Event()

    def release(self) -> None:
        self.open.set()

    def __iter__(self):
        for i, c in enumerate(self.chunks):
            if i >= self.preload and not self.open.is_set():
                if not self.open.wait(WAIT_S):
                    raise RuntimeError("gate never released")
            yield c


def _serial(cfg, groups) -> np.ndarray:
    """Oracle: the direct single-stream filter on the same chunks."""
    den = StreamingDenoiser(cfg)
    state = den.init()
    for k, g in enumerate(groups):
        state = den.ingest(state, np.asarray(g), step=k)
    return np.asarray(den.finalize(state))


def _fleet(clock, cfg_window, *, max_executors, max_sessions):
    return FleetScheduler(
        clock=clock,
        slots_per_executor=2,
        max_executors=max_executors,
        max_sessions=max_sessions,
        max_waiting=64,  # the in-flight cap is the (deterministic) limiter
        coalesce_ms=0.0,
        slos=[admission_pressure_slo(budget=REJECT_BUDGET, window_s=cfg_window)],
        slo_eval_every_s=1e9,  # the autoscaler owns the evaluation cadence
    )


def _jain(xs: Sequence[float]) -> float:
    xs = [float(x) for x in xs]
    if not xs or not any(xs):
        return 0.0
    return sum(xs) ** 2 / (len(xs) * sum(x * x for x in xs))


# ---------------------------------------------------------------------------
# scaleup: flash crowd -> breach -> pool growth -> recovery
# ---------------------------------------------------------------------------
def _scaleup_cell(cfg, chunks, trace_out: str) -> dict:
    clock = FakeClock()
    tr = obs.get_tracer()
    was_enabled, old_clock = tr.enabled, tr.clock
    tr.clear()
    obs.configure(enabled=True, clock=clock)
    rng = np.random.default_rng(SEED)
    arrivals = flash_crowd_schedule(
        0.5, 2.5, burst_at_s=3.0, burst_s=2.0, duration_s=6.0, rng=rng
    )
    trace = build_trace(
        [TenantProfile("hold", cfg)],
        arrivals,
        rng=rng,
        min_groups=cfg.num_groups,
        max_groups=cfg.num_groups,
    )
    fleet = _fleet(clock, WINDOW_S, max_executors=3, max_sessions=6)
    scaler = Autoscaler(
        fleet,
        min_executors=1,
        initial_executors=1,  # pool starts small; the crowd must grow it
        breach_streak=1,
        clear_streak=1,
        cooldown_down_s=1e9,  # the cell measures growth, not shrink
    )
    gates: list[_Gate] = []
    handles = []
    admitted = rejected = 0
    first_reject_t: float | None = None

    def submit(ev) -> bool:
        nonlocal admitted, rejected, first_reject_t
        gate = _Gate(chunks)
        try:
            h = fleet.submit(Session(config=cfg, source=gate, name=ev.session))
        except AdmissionError:
            rejected += 1
            if first_reject_t is None:
                first_reject_t = clock.now()
            return False
        gates.append(gate)
        handles.append(h)
        admitted += 1
        return True

    try:
        scaler.evaluate()  # baseline metric snapshot at t=0
        replay_trace(
            trace, clock=clock, submit=submit,
            on_tick=lambda now: scaler.evaluate(),
        )
        scale_marks = [m for m in fleet.timeline if m[0] == "scale-up"]
        if first_reject_t is None or not scale_marks:
            raise SystemExit(
                f"flash crowd produced no scale-up (rejected={rejected}, "
                f"marks={scale_marks})"
            )
        reaction_s = scale_marks[0][2] - first_reject_t
        # drain the crowd, then prove the breach clears: clean traffic
        # through a fresh window must flip the verdict back to ok
        for g in gates:
            g.release()
        for h in handles:
            h.result(timeout=WAIT_S)
        # the final arrival lands *after* the last snapshot, so the first
        # clean tick still sees crowd rejections in its window — give the
        # verdict a few clean windows to flip back to ok (each advance
        # stays within the engine's snapshot-retention horizon, 1.5x the
        # widest window, so the previous tick remains the delta baseline)
        final = None
        for i in range(6):
            clock.advance(WINDOW_S)
            fleet.submit(
                Session(config=cfg, source=iter(chunks), name=f"clean{i}")
            ).result(timeout=WAIT_S)
            final = scaler.evaluate()
            if not final.breached:
                break
        state = scaler.state()
        fleet.shutdown()
        doc = tr.export_chrome(trace_out)
    finally:
        obs.configure(enabled=was_enabled, clock=old_clock)
        tr.clear()
    events = obs.validate_chrome_trace(doc)
    names = [e["name"] for e in events if e.get("ph") == "i"]
    missing = {"slo_breach", "fleet.scale_up", "slo_recovered"} - set(names)
    if missing:
        raise SystemExit(f"scaleup trace missing instants: {sorted(missing)}")
    if final.breached:
        raise SystemExit("breach did not clear after the crowd drained")
    return {
        "reaction_s": reaction_s,
        "arrivals": len(trace),
        "admitted": admitted,
        "rejected": rejected,
        "scale_ups": state["scale_ups"],
        "pool_target": state["target_executors"],
        "trace_events": len(events),
    }


# ---------------------------------------------------------------------------
# sustained: elastic capacity at the fixed admission SLO
# ---------------------------------------------------------------------------
def _sustained_cell(cfg, chunks, *, max_executors: int = 3) -> dict:
    clock = FakeClock()
    fleet = _fleet(
        clock, WINDOW_S, max_executors=max_executors,
        max_sessions=2 * max_executors,
    )
    scaler = Autoscaler(
        fleet,
        min_executors=1,
        initial_executors=1,
        breach_streak=1,
        clear_streak=1,
        cooldown_down_s=1e9,
    )
    scaler.evaluate()
    gates: list[_Gate] = []
    handles = []
    sustained = 0
    for i in range(4 * max_executors):
        gate = _Gate(chunks)
        sess = Session(config=cfg, source=gate, name=f"n{i}")
        try:
            handles.append(fleet.submit(sess))
        except AdmissionError:
            # one autoscaler tick, one retry: the backoff rung in
            # miniature (the real ladder widens this via BackoffPolicy)
            clock.advance(WINDOW_S)
            scaler.evaluate()
            try:
                handles.append(fleet.submit(sess))
            except AdmissionError:
                break
        gates.append(gate)
        sustained += 1
    state = scaler.state()
    for g in gates:
        g.release()
    for h in handles:
        h.result(timeout=WAIT_S)
    fleet.shutdown()
    return {
        "sustained_sessions": sustained,
        "pool_target": state["target_executors"],
        "scale_ups": state["scale_ups"],
    }


# ---------------------------------------------------------------------------
# ladder: capacity-capped overload -> degrade/shed -> restore, bit-exact
# ---------------------------------------------------------------------------
def _ladder_cell(cfg, chunks) -> dict:
    ref = _serial(cfg, chunks)
    clock = FakeClock()
    tr = obs.get_tracer()
    was_enabled, old_clock = tr.enabled, tr.clock
    tr.clear()
    obs.configure(enabled=True, clock=clock)
    fleet = _fleet(clock, WINDOW_S, max_executors=1, max_sessions=2)
    scaler = Autoscaler(
        fleet, min_executors=1, max_executors=1,
        breach_streak=1, clear_streak=1, cooldown_down_s=1e9,
    )
    try:
        scaler.evaluate()
        gate_gold = _Gate(chunks)               # lossless, high priority
        gate_be = _Gate(chunks, preload=1)      # folds one group, then holds
        h_gold = fleet.submit(
            Session(config=cfg, source=gate_gold, name="gold", priority=10)
        )
        h_be = fleet.submit(
            Session(config=cfg, source=gate_be, name="best-effort", priority=0)
        )
        # let best-effort fold its preloaded group so the shed victim has
        # served non-zero work (the fairness figure needs the asymmetry)
        deadline = time.monotonic() + WAIT_S
        while time.monotonic() < deadline:
            rows = fleet.health(evaluate_slos=False).sessions
            if any(
                r["name"] == "best-effort" and r["steps"] >= 1 for r in rows
            ):
                break
            time.sleep(0.005)
        # sustained overload: each breached tick climbs one rung
        actions = []
        for tick in range(4):
            for i in range(3):
                try:
                    fleet.submit(
                        Session(
                            config=cfg, source=iter(chunks),
                            name=f"ov{tick}-{i}",
                        )
                    )
                except AdmissionError:
                    pass
            clock.advance(1.0)
            actions.append(scaler.evaluate().action)
        if actions != ["degrade", "degrade", "degrade", "shed"]:
            raise SystemExit(f"ladder walk went {actions}")
        _, rep_be = h_be.result(timeout=WAIT_S)  # shed victim finalizes
        # breach clears: clean traffic, descend one rung per clean tick
        # (advance a hair over one window — past the SLO window but inside
        # the engine's snapshot-retention horizon)
        while fleet.degradation_level > 0:
            clock.advance(1.25 * WINDOW_S)
            fleet.submit(
                Session(
                    config=cfg, source=iter(chunks),
                    name=f"cl{fleet.degradation_level}",
                )
            ).result(timeout=WAIT_S)
            if scaler.evaluate().action != "restore":
                raise SystemExit("clean tick did not restore a rung")
        gate_gold.release()
        out_gold, rep_gold = h_gold.result(timeout=WAIT_S)
        fleet.shutdown()
        doc = tr.export_chrome()
    finally:
        obs.configure(enabled=was_enabled, clock=old_clock)
        tr.clear()
    np.testing.assert_array_equal(np.asarray(out_gold), ref)
    events = obs.validate_chrome_trace(doc)
    inst = [e for e in events if e.get("ph") == "i"]
    for needed, sess in (("degrade", "gold"), ("restore", "gold"),
                         ("fleet.shed", "best-effort")):
        if not any(
            e["name"] == needed and e.get("args", {}).get("session") == sess
            for e in inst
        ):
            raise SystemExit(f"ladder trace missing {needed}@{sess}")
    fairness = _jain([rep_gold.groups, rep_be.groups])
    return {
        "jain_fairness": fairness,
        "gold_groups": rep_gold.groups,
        "shed_groups": rep_be.groups,
        "bit_exact_restore": True,
    }


def run(
    quick: bool = True,
    *,
    smoke: bool = False,
    assert_scaleup: bool = False,
    trace_out: str = "table17_trace.json",
) -> None:
    # tiny frames throughout: every cell measures control-plane behaviour
    # in virtual time, not kernel throughput, so shape is irrelevant
    cfg = bench_config(
        True, num_groups=4, frames_per_group=8, height=8, width=32
    )
    chunks = [np.asarray(c) for c in PrismSource(cfg).groups()]

    up = _scaleup_cell(cfg, chunks, trace_out)
    emit(
        "table17/scaleup",
        up["reaction_s"] * 1e6,
        f"reaction_s={up['reaction_s']:.3f};scale_ups={up['scale_ups']};"
        f"admitted={up['admitted']};rejected={up['rejected']}",
    )
    if assert_scaleup:
        if up["reaction_s"] > 2 * WINDOW_S:
            raise SystemExit(
                f"scale-up reaction {up['reaction_s']:.2f}s exceeds two "
                f"{WINDOW_S:.0f}s SLO windows"
            )
        print(
            f"# scaleup assertion ok: reaction {up['reaction_s']:.2f}s, "
            f"breach->scale_up->recovered chain in {trace_out}"
        )

    su = _sustained_cell(cfg, chunks)
    emit(
        "table17/sustained",
        0.0,
        f"sustained={su['sustained_sessions']};"
        f"pool_target={su['pool_target']};scale_ups={su['scale_ups']}",
    )

    lad = _ladder_cell(cfg, chunks)
    emit(
        "table17/ladder",
        0.0,
        f"jain={lad['jain_fairness']:.4f};"
        f"gold_groups={lad['gold_groups']};shed_groups={lad['shed_groups']}",
    )

    common_config = {
        "G": cfg.num_groups,
        "N": cfg.frames_per_group,
        "H": cfg.height,
        "W": cfg.width,
        "window_s": WINDOW_S,
        "reject_budget": REJECT_BUDGET,
        "seed": SEED,
    }
    bench_record(
        "autoscale_capacity",
        kind="autoscale",
        config=common_config,
        sustained_sessions=su["sustained_sessions"],
        pool_target=su["pool_target"],
        scale_ups=su["scale_ups"],
        jain_fairness=round(lad["jain_fairness"], 4),
    )
    bench_record(
        "autoscale_reaction",
        kind="autoscale_reaction",
        config=common_config,
        reaction_s=round(up["reaction_s"], 4),
        rejected=up["rejected"],
        admitted=up["admitted"],
    )


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="accepted for parity")
    ap.add_argument(
        "--smoke", action="store_true", help="alias — all cells are cheap"
    )
    ap.add_argument(
        "--assert-scaleup",
        action="store_true",
        help="exit non-zero unless the flash crowd triggers a scale-up "
        "within two SLO windows of the first rejection and the "
        "breach -> scale_up -> recovered chain survives the "
        "Chrome-trace export",
    )
    ap.add_argument(
        "--trace-out",
        default="table17_trace.json",
        help="where to write the scaleup-cell Chrome-trace artifact",
    )
    args = ap.parse_args(argv)
    run(
        quick=not args.full,
        smoke=args.smoke,
        assert_scaleup=args.assert_scaleup,
        trace_out=args.trace_out,
    )


if __name__ == "__main__":
    main()
