"""Table 14 (framework extension): fault-tolerant fleet serving.

Table 11 measured what co-scheduling buys when tenants share one device;
this table measures what the :class:`~repro.serve.FleetScheduler` layer
on top of it *costs* and *guarantees*:

* **scaling cells** — ``sessions`` uniform streams spread over
  ``executors`` pool members (``slots_per_executor`` sized so placement
  spills across the pool): aggregate fps and worst per-session p99
  service latency vs executor count, with per-group checkpointing ON —
  the steady-state overhead a fleet operator actually pays.
* **kill cell** — a scripted :class:`~repro.serve.faults.FaultPlan`
  crashes one executor mid-stream. Every hosted session must restore its
  newest checkpoint, re-fold its replay log on a surviving executor and
  finish with the bit-identical output contract the recovery tests pin
  down; the point records the kill-to-recovered latency distribution
  from ``fleet.recovery_latencies_s()`` (real clock here — the marks are
  wall timestamps, unlike the ``FakeClock`` unit tests).

Points land in ``BENCH_denoise.json`` as the ``fleet`` trajectory
(``kind="fleet"``): aggregate fps, per-session p99, checkpoint counts,
and — for the kill cell — ``kill_to_recovered_ms`` plus restart
accounting. Run directly for the CI smoke cycle::

    python -m benchmarks.table14_fleet --smoke --assert-recovery

``--smoke`` shrinks the stream and runs only one scaling cell plus the
kill cell; ``--assert-recovery`` exits non-zero unless the scripted kill
recovered every session (restart counted, no give-ups) within
``RECOVERY_BUDGET_S``.
"""

from __future__ import annotations

import argparse
import tempfile
import time
from typing import Sequence

import jax
import numpy as np

from benchmarks.common import (
    PAPER_H,
    PAPER_W,
    bench_config,
    bench_record,
    emit,
    emit_report,
)
from repro.data.prism import PrismSource
from repro.serve import FaultPlan, FleetScheduler, Session

EXECUTOR_SWEEP = (1, 2, 3)
SESSIONS_PER_EXECUTOR = 2
RING_SLOTS = 2
KILL_AT_STEP = 2        # ex0 dies after folding groups 0 and 1
RECOVERY_BUDGET_S = 15.0  # kill -> first post-recovery fold, wall clock


def _run_cell(
    cfg, chunks, *, executors: int, sessions: int, ckpt_dir: str,
    faults: FaultPlan | None = None,
):
    """One fleet run: ``sessions`` uniform streams over an ``executors``-
    wide pool, per-group checkpoints on. Returns (wall_s, reports, fleet
    telemetry dict)."""
    fleet = FleetScheduler(
        checkpoint_dir=ckpt_dir,
        faults=faults,
        slots_per_executor=max(1, sessions // executors),
        max_executors=executors,
        max_sessions=sessions,
    )
    try:
        t0 = time.perf_counter()
        handles = [
            fleet.submit(
                Session(
                    config=cfg,
                    source=iter(chunks),
                    name=f"s{i}",
                    num_slots=RING_SLOTS,
                )
            )
            for i in range(sessions)
        ]
        outs = [h.result(timeout=600) for h in handles]
        wall = time.perf_counter() - t0
        telemetry = {
            "events": list(fleet.events),
            "recovery_s": fleet.recovery_latencies_s(),
        }
    finally:
        fleet.shutdown()
    return wall, [rep for _, rep in outs], telemetry


def run(
    quick: bool = True,
    *,
    smoke: bool = False,
    assert_recovery: bool = False,
) -> None:
    cfg = bench_config(
        quick,
        num_groups=6 if smoke else 10,
        frames_per_group=40 if smoke else (240 if quick else 600),
        height=16 if smoke else PAPER_H,
        width=64 if smoke else PAPER_W,
    )
    chunks = [jax.device_put(np.asarray(c)) for c in PrismSource(cfg).groups()]
    jax.block_until_ready(chunks)

    sweep = (2,) if smoke else EXECUTOR_SWEEP
    with tempfile.TemporaryDirectory(prefix="fleet-bench-") as root:
        # -- scaling: fps / p99 vs executor count, checkpointing on ---------
        for n_exec in sweep:
            n_sessions = SESSIONS_PER_EXECUTOR * n_exec
            wall, reports, _ = _run_cell(
                cfg,
                chunks,
                executors=n_exec,
                sessions=n_sessions,
                ckpt_dir=f"{root}/scale{n_exec}",
            )
            tag = f"table14/scale/e{n_exec}/n{n_sessions}"
            frames = sum(r.frames for r in reports)
            agg_fps = frames / max(wall, 1e-9)
            p99 = max(r.latency_p99_ms for r in reports)
            ckpts = sum(r.checkpoints for r in reports)
            for r in reports:
                emit_report(f"{tag}/{r.session}", r)
            emit(
                tag,
                wall * 1e6 / max(frames, 1),
                f"agg_fps={agg_fps:.0f};p99_ms={p99:.1f};checkpoints={ckpts}",
            )
            bench_record(
                "fleet",
                kind="fleet",
                cell="scale",
                config={
                    "G": cfg.num_groups,
                    "N": cfg.frames_per_group,
                    "H": cfg.height,
                    "W": cfg.width,
                    "backend": cfg.backend,
                    "executors": n_exec,
                    "sessions": n_sessions,
                    "ring_slots": RING_SLOTS,
                    "checkpoint_every": 1,
                },
                aggregate_fps=round(agg_fps, 1),
                session_p99_ms=round(p99, 3),
                checkpoints=ckpts,
            )

        # -- kill cell: scripted crash, checkpointed recovery ---------------
        n_exec, n_sessions = 2, 2 * SESSIONS_PER_EXECUTOR
        plan = FaultPlan().crash("ex0", at_step=KILL_AT_STEP)
        wall, reports, telemetry = _run_cell(
            cfg,
            chunks,
            executors=n_exec,
            sessions=n_sessions,
            ckpt_dir=f"{root}/kill",
            faults=plan,
        )
        tag = f"table14/kill/e{n_exec}/n{n_sessions}"
        frames = sum(r.frames for r in reports)
        restarts = sum(r.restarts for r in reports)
        recoveries = telemetry["recovery_s"]
        give_ups = [e for e in telemetry["events"] if e.startswith("give-up@")]
        kill_ms = max(recoveries) * 1e3 if recoveries else float("nan")
        for r in reports:
            emit_report(f"{tag}/{r.session}", r)
        emit(
            tag,
            wall * 1e6 / max(frames, 1),
            f"restarts={restarts};recovered={len(recoveries)};"
            f"kill_to_recovered_ms={kill_ms:.1f}",
        )
        bench_record(
            "fleet",
            kind="fleet",
            cell="kill",
            config={
                "G": cfg.num_groups,
                "N": cfg.frames_per_group,
                "H": cfg.height,
                "W": cfg.width,
                "backend": cfg.backend,
                "executors": n_exec,
                "sessions": n_sessions,
                "ring_slots": RING_SLOTS,
                "checkpoint_every": 1,
                "kill_at_step": KILL_AT_STEP,
            },
            aggregate_fps=round(frames / max(wall, 1e-9), 1),
            session_p99_ms=round(max(r.latency_p99_ms for r in reports), 3),
            restarts=restarts,
            recovered_sessions=len(recoveries),
            give_ups=len(give_ups),
            kill_to_recovered_ms=round(kill_ms, 2),
        )
        if assert_recovery:
            # every session finished (result() above would have raised),
            # the kill actually fired, nobody was given up on, and the
            # first post-recovery fold landed inside the budget
            if restarts < 1:
                raise SystemExit(
                    f"kill cell recorded no restarts (events={telemetry['events']})"
                )
            if give_ups:
                raise SystemExit(f"kill cell gave up on sessions: {give_ups}")
            if not recoveries:
                raise SystemExit(
                    "kill cell recorded no session-recovered marks "
                    f"(events={telemetry['events']})"
                )
            if max(recoveries) > RECOVERY_BUDGET_S:
                raise SystemExit(
                    f"kill-to-recovered {max(recoveries):.2f}s exceeds "
                    f"budget {RECOVERY_BUDGET_S}s"
                )
            print(
                f"# recovery assertion ok: {len(recoveries)} sessions, "
                f"worst {kill_ms:.1f}ms"
            )


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale streams")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny stream, one scaling cell + the kill cell",
    )
    ap.add_argument(
        "--assert-recovery",
        action="store_true",
        help="exit non-zero unless the scripted kill recovered every "
        "session within the budget",
    )
    args = ap.parse_args(argv)
    run(
        quick=not args.full,
        smoke=args.smoke,
        assert_recovery=args.assert_recovery,
    )


if __name__ == "__main__":
    main()
