"""Paper Tables 8-10: buffer-then-process vs inline preprocessing.

The paper's headline systems claim: the buffering phase of CPU/GPU
workflows alone costs about as much as the entire inline pipeline. We
measure both workflows over the same synthetic acquisition and report the
buffering fraction.

New in this table: the inline executor's double-buffering. ``run_inline``
now stages chunk k+1 (frame synthesis + host->device transfer) while
chunk k computes; we run the sync (``prefetch=False``, the pre-PR
behaviour) and prefetched paths over identical live sources at the
paper's default config and record the ratio to BENCH_denoise.json. On
this container the synthetic camera is far slower than the denoise step,
so the prefetched path is acquisition-bound — compute hides entirely
under the camera (the paper's inline argument); ``overlap_frac`` reports
how much staging time was hidden.
"""

from __future__ import annotations

from benchmarks.common import (
    PAPER_G,
    PAPER_H,
    PAPER_N,
    PAPER_W,
    bench_config,
    bench_record,
    emit,
    emit_report,
)
from repro.core.denoise import DenoiseConfig
from repro.core.streaming import run_buffered, run_inline
from repro.data.prism import PrismSource


def run(quick: bool = True) -> None:
    cfg = bench_config(quick, frames_per_group=100 if quick else 200)
    interval = 100.0  # µs/frame acquisition rate for both workflows

    groups = list(PrismSource(cfg).groups())
    run_inline(cfg, iter(groups))      # warm the jit caches
    run_buffered(cfg, iter(groups))
    src = PrismSource(cfg)
    _, inline = run_inline(cfg, iter(src.groups()), interval_us=interval)
    emit(
        "table10/inline_total",
        inline.elapsed_s * 1e6 / inline.frames,
        f"buffering_s=0.0;total_s={inline.elapsed_s:.3f}",
    )

    src = PrismSource(cfg)
    _, buf = run_buffered(cfg, iter(src.groups()), interval_us=interval)
    emit(
        "table10/buffered_total",
        buf.elapsed_s * 1e6 / buf.frames,
        f"buffering_s={buf.buffering_s:.3f};compute_s={buf.compute_s:.3f}",
    )
    frac = buf.buffering_s / max(buf.elapsed_s, 1e-9)
    emit(
        "table10/buffering_fraction",
        frac * 100,
        "percent of buffered workflow spent staging (paper: ~100% of FPGA total)",
    )
    emit("table10/paper_v100_total", 0.478e6 / 8000, "paper 2-bank V100 incl. I/O")
    emit("table10/paper_fpga_total", 0.4565e6 / 8000, "paper 2-bank FPGA inline")

    # -- sync vs double-buffered inline, paper default config --------------
    pcfg = DenoiseConfig(
        num_groups=PAPER_G,
        frames_per_group=PAPER_N if not quick else 400,
        height=PAPER_H,
        width=PAPER_W,
        backend="xla",
    )
    run_inline(pcfg, iter(PrismSource(pcfg).groups()))  # warm
    _, sync = run_inline(pcfg, PrismSource(pcfg).groups(), prefetch=False)
    _, pre = run_inline(pcfg, PrismSource(pcfg).groups(), prefetch=True)
    ratio = sync.elapsed_s / max(pre.elapsed_s, 1e-9)
    emit(
        "table8/inline_sync",
        sync.elapsed_s * 1e6 / sync.frames,
        f"total_s={sync.elapsed_s:.3f};transfer_s={sync.transfer_s:.3f}",
    )
    emit(
        "table8/inline_prefetch",
        pre.elapsed_s * 1e6 / pre.frames,
        f"total_s={pre.elapsed_s:.3f};speedup={ratio:.2f}x;"
        f"overlap_frac={pre.overlap_frac:.2f}",
    )
    # full rows: transfer/stall/overlap + ring fields (dropped pre-PR 2)
    emit_report("table8/inline_sync", sync)
    emit_report("table8/inline_prefetch", pre)
    bench_record(
        "inline_prefetch_vs_sync",
        kind="speedup",
        config={
            "G": pcfg.num_groups,
            "N": pcfg.frames_per_group,
            "H": pcfg.height,
            "W": pcfg.width,
            "backend": "xla",
            "source": "live synthesis",
        },
        baseline="sync ingest (stage then compute, serial)",
        candidate="double-buffered ingest (stage k+1 under compute k)",
        baseline_s=sync.elapsed_s,
        candidate_s=pre.elapsed_s,
        speedup=round(ratio, 3),
        overlap_frac=round(pre.overlap_frac, 3),
        note=(
            "acquisition-bound: the synthetic camera is ~10x slower than the "
            "denoise step, and on a 2-core host the staging worker contends "
            "with XLA's compute threads, so overlap nets out ~1.0x here; the "
            "fused-path records above carry the speedup on this container"
        ),
    )
