"""Paper Tables 8-10: buffer-then-process vs inline preprocessing.

The paper's headline systems claim: the buffering phase of CPU/GPU
workflows alone costs about as much as the entire inline pipeline. We
measure both workflows over the same synthetic acquisition and report the
buffering fraction.
"""

from __future__ import annotations

from benchmarks.common import bench_config, emit
from repro.core.streaming import run_buffered, run_inline
from repro.data.prism import PrismSource


def run(quick: bool = True) -> None:
    cfg = bench_config(quick, frames_per_group=100 if quick else 200)
    interval = 100.0  # µs/frame acquisition rate for both workflows

    groups = list(PrismSource(cfg).groups())
    run_inline(cfg, iter(groups))      # warm the jit caches
    run_buffered(cfg, iter(groups))
    src = PrismSource(cfg)
    _, inline = run_inline(cfg, iter(src.groups()), interval_us=interval)
    emit(
        "table10/inline_total",
        inline.elapsed_s * 1e6 / inline.frames,
        f"buffering_s=0.0;total_s={inline.elapsed_s:.3f}",
    )

    src = PrismSource(cfg)
    _, buf = run_buffered(cfg, iter(src.groups()), interval_us=interval)
    emit(
        "table10/buffered_total",
        buf.elapsed_s * 1e6 / buf.frames,
        f"buffering_s={buf.buffering_s:.3f};compute_s={buf.compute_s:.3f}",
    )
    frac = buf.buffering_s / max(buf.elapsed_s, 1e-9)
    emit(
        "table10/buffering_fraction",
        frac * 100,
        "percent of buffered workflow spent staging (paper: ~100% of FPGA total)",
    )
    emit("table10/paper_v100_total", 0.478e6 / 8000, "paper 2-bank V100 incl. I/O")
    emit("table10/paper_fpga_total", 0.4565e6 / 8000, "paper 2-bank FPGA inline")
