"""Table 16 (framework extension): SLO judgement-tier characterization.

Four cells over ``repro.obs.slo`` + the serve wiring:

* **detection** — breach-detection latency of the multi-window burn-rate
  evaluator under a FakeClock-scripted deadline-miss overload: ~30 s of
  clean service, then a sustained 30% miss rate against a 5% objective.
  Pure virtual time (zero wall-clock sleeps), so the number is exact and
  deterministic: seconds from overload onset to the first ``breached``
  verdict. ``--assert-detection`` requires it within one evaluation
  window and requires the attributed ``slo_breach`` instant to survive a
  validated Chrome-trace export round-trip.
* **kill** — end-to-end wiring proof on a real fleet: a scripted
  executor crash recovers through the checkpoint path, the recovery
  latency lands in ``fleet.recovery_s``, and a recovery-time SLO with a
  sub-recovery target must breach — ``fleet.executor_dead`` and the
  attributed ``slo_breach`` both present in the exported trace.
* **overhead** — enabled-SLO serve hot path (engine ticked after every
  cohort fold) vs a no-SLO control, measured with table15's order-
  balanced min-of-k paired-ratio discipline and gated by the same
  ``OVERHEAD_BUDGET`` (``min(median, floor) <= 1.02``) under
  ``--assert-overhead``. The per-evaluation cost (``eval_us``) comes
  from the engine's own ``eval_time_s / evaluations`` accounting.
* **headroom** — agreement between the health tier's capacity reference
  (``repro.core.latency_model`` camera-gated floor) and a measured
  streaming pass. Informational off-FPGA: the model is camera-gated at
  57 µs/frame *regardless of shape*, so tiny smoke frames on a CPU can
  land either side of it — the recorded ratio documents where this host
  sits relative to the reference the health report's headroom column
  uses.

Run directly for the CI smoke cycle::

    python -m benchmarks.table16_slo --smoke --assert-detection
"""

from __future__ import annotations

import argparse
import statistics
import tempfile
import time
from typing import Sequence

import jax
import numpy as np

from benchmarks.common import bench_config, bench_record, emit
from benchmarks.table15_observability import OVERHEAD_BUDGET, _paired_ratios
from repro import obs
from repro.core.streaming import run_pipelined
from repro.data.prism import PrismSource
from repro.obs.health import capacity_reference
from repro.serve import FaultPlan, FleetScheduler, Session
from repro.serve.faults import FakeClock
from repro.serve.scheduler import SessionScheduler

RING_SLOTS = 2
WINDOW_S = 10.0          # detection cell: short evaluation window
TICK_S = 0.5             # virtual seconds per scripted tick
HEALTHY_TICKS = 60       # 30 virtual seconds of clean service
OVERLOAD_TICKS = 40      # ceiling; breach must land well before
GROUPS_PER_TICK = 10
MISSES_PER_TICK = 3      # 30% miss rate against a 5% objective
MISS_TARGET = 0.05
KILL_AT_STEP = 2


def _detection_cell(trace_out: str) -> dict:
    """FakeClock-scripted overload: exact breach-detection latency."""
    clock = FakeClock()
    reg = obs.MetricsRegistry()
    spec = obs.SloSpec(
        name="deadline-miss-rate[s0]",
        kind="deadline_miss_rate",
        target=MISS_TARGET,
        window_s=WINDOW_S,
        bad_metric="serve.deadline_misses",
        total_metric="serve.latency_s",
        labels={"session": "s0"},
    )
    engine = obs.SloEngine([spec], reg, clock=clock, eval_every_s=TICK_S)
    tr = obs.get_tracer()
    was_enabled, old_clock = tr.enabled, tr.clock
    tr.clear()
    obs.configure(enabled=True, clock=clock)
    lat = reg.histogram("serve.latency_s", session="s0")
    misses = reg.counter("serve.deadline_misses", session="s0")

    def tick(miss: bool) -> list | None:
        clock.advance(TICK_S)
        for _ in range(GROUPS_PER_TICK):
            lat.observe(0.01)
        if miss:
            misses.inc(MISSES_PER_TICK)
        return engine.maybe_evaluate()

    detection_s = None
    try:
        for _ in range(HEALTHY_TICKS):
            verdicts = tick(miss=False)
            if verdicts and any(v.breached for v in verdicts):
                raise SystemExit("SLO breached during the healthy phase")
        overload_t0 = clock.now()
        for _ in range(OVERLOAD_TICKS):
            verdicts = tick(miss=True)
            if verdicts and any(v.breached for v in verdicts):
                detection_s = clock.now() - overload_t0
                break
        doc = tr.export_chrome(trace_out)
    finally:
        obs.configure(enabled=was_enabled, clock=old_clock)
        tr.clear()
    if detection_s is None:
        raise SystemExit(
            f"overload never breached within {OVERLOAD_TICKS * TICK_S}s"
        )
    events = obs.validate_chrome_trace(doc)
    breaches = [e for e in events if e["name"] == "slo_breach"]
    if not breaches:
        raise SystemExit("no slo_breach instant survived the trace export")
    attributed = [
        e for e in breaches if e.get("args", {}).get("session") == "s0"
    ]
    if not attributed:
        raise SystemExit(
            f"slo_breach instants lack session attribution: {breaches}"
        )
    return {
        "detection_s": detection_s,
        "detection_windows": detection_s / WINDOW_S,
        "evaluations": engine.evaluations,
        "eval_us": engine.eval_time_s / max(1, engine.evaluations) * 1e6,
        "trace_events": len(events),
    }


def _kill_cell(cfg, chunks, ckpt_dir: str) -> dict:
    """Real fleet, scripted kill: recovery latency must trip a
    sub-recovery recovery-time SLO, attributed in the trace."""
    tr = obs.get_tracer()
    was_enabled = tr.enabled
    tr.clear()
    obs.configure(enabled=True)
    specs = [
        obs.SloSpec(
            name="fleet-recovery-time",
            kind="recovery_time",
            # any real recovery exceeds this: the cell proves the
            # observation -> evaluation -> trace wiring, not a budget
            target=1e-6,
            window_s=WINDOW_S,
            metric="fleet.recovery_s",
            percentile=100.0,
            aggregate=True,
        )
    ]
    fleet = FleetScheduler(
        checkpoint_dir=ckpt_dir,
        checkpoint_every=1,
        faults=FaultPlan().crash("ex0", at_step=KILL_AT_STEP),
        slots_per_executor=2,
        max_executors=2,
        max_sessions=2,
        slos=specs,
        slo_eval_every_s=0.05,
    )
    try:
        handles = [
            fleet.submit(
                Session(
                    config=cfg,
                    source=iter(chunks),
                    name=f"s{i}",
                    num_slots=RING_SLOTS,
                )
            )
            for i in range(2)
        ]
        reports = [h.result(timeout=600)[1] for h in handles]
        verdicts = fleet.slo_engine.evaluate()
        recoveries = fleet.recovery_latencies_s()
        doc = tr.export_chrome()
    finally:
        fleet.shutdown()
        obs.configure(enabled=was_enabled)
        tr.clear()
    if sum(r.restarts for r in reports) < 1:
        raise SystemExit("scripted kill produced no session restart")
    if not recoveries:
        raise SystemExit("no recovery latency was recorded")
    verdict = next(v for v in verdicts if v.spec == "fleet-recovery-time")
    events = obs.validate_chrome_trace(doc)
    names = {e["name"] for e in events}
    missing = {"fleet.executor_dead", "slo_breach"} - names
    if missing:
        raise SystemExit(f"kill-cell trace missing events: {sorted(missing)}")
    return {
        "recovery_s": max(recoveries),
        "breached": verdict.breached,
        "trace_events": len(events),
    }


def _overhead_cell(cfg, chunks, pairs: int) -> dict:
    """Enabled-SLO serve path vs no-SLO control, paired min-of-k."""

    def serve_once(slos) -> float:
        t0 = time.perf_counter()
        with SessionScheduler(
            slots_per_executor=2,
            max_executors=1,
            slos=slos,
            slo_eval_every_s=0.05,
        ) as sched:
            handles = [
                sched.submit(
                    Session(
                        config=cfg,
                        source=iter(chunks),
                        name=f"s{i}",
                        num_slots=RING_SLOTS,
                    )
                )
                for i in range(2)
            ]
            for h in handles:
                h.result(timeout=600)
            if sched.slo_engine is not None:
                serve_once.last_engine = sched.slo_engine
        return time.perf_counter() - t0

    serve_once.last_engine = None

    def control() -> float:
        return serve_once(())

    def with_slos() -> float:
        return serve_once(obs.default_serve_slos(window_s=5.0))

    ratios, floor = _paired_ratios(control, with_slos, pairs)
    engine = serve_once.last_engine
    eval_us = (
        engine.eval_time_s / max(1, engine.evaluations) * 1e6
        if engine is not None
        else 0.0
    )
    return {
        "overhead_ratio": statistics.median(ratios),
        "overhead_floor": floor,
        "serve_eval_us": eval_us,
        "serve_evaluations": engine.evaluations if engine else 0,
    }


def _headroom_cell(cfg, chunks) -> dict:
    """Measured streaming fps vs the health tier's capacity model."""
    run_pipelined(cfg, iter(chunks), num_slots=RING_SLOTS)  # warm caches
    t0 = time.perf_counter()
    run_pipelined(cfg, iter(chunks), num_slots=RING_SLOTS)
    elapsed = time.perf_counter() - t0
    frames = cfg.num_groups * cfg.frames_per_group
    measured_fps = frames / elapsed
    cap = capacity_reference(
        height=cfg.height,
        width=cfg.width,
        num_groups=cfg.num_groups,
        frames_per_group=cfg.frames_per_group,
    )
    return {
        "measured_fps": measured_fps,
        "model_fps": cap["model_fps"],
        "headroom_agreement": measured_fps / cap["model_fps"],
    }


def run(
    quick: bool = True,
    *,
    smoke: bool = False,
    assert_detection: bool = False,
    assert_overhead: bool = False,
    trace_out: str = "table16_trace.json",
) -> None:
    # -- detection: pure virtual time, shape-independent --------------------
    det = _detection_cell(trace_out)
    emit(
        "table16/detection",
        det["detection_s"] * 1e6,
        f"detection_s={det['detection_s']:.2f};"
        f"windows={det['detection_windows']:.3f};"
        f"eval_us={det['eval_us']:.1f}",
    )
    if assert_detection:
        if det["detection_windows"] > 1.0:
            raise SystemExit(
                f"breach detection took {det['detection_s']:.2f}s — more "
                f"than one {WINDOW_S:.0f}s evaluation window"
            )
        print(
            f"# detection assertion ok: breach in {det['detection_s']:.2f}s "
            f"({det['detection_windows']:.2f} windows), attributed "
            f"slo_breach in {trace_out}"
        )

    # small frames throughout the serve cells: the SLO engine's cost is
    # per-evaluation, not per-pixel, and the kill cell documents event
    # vocabulary (both shape-independent — same reasoning as table15's
    # trace artifact)
    cfg = bench_config(
        True, num_groups=6, frames_per_group=40, height=16, width=64
    )
    chunks = [jax.device_put(np.asarray(c)) for c in PrismSource(cfg).groups()]
    jax.block_until_ready(chunks)

    # -- kill: wiring proof on a real fleet ---------------------------------
    with tempfile.TemporaryDirectory(prefix="table16-ckpt-") as root:
        kill = _kill_cell(cfg, chunks, f"{root}/ckpt")
    emit(
        "table16/kill",
        kill["recovery_s"] * 1e6,
        f"recovery_s={kill['recovery_s']:.3f};breached={kill['breached']}",
    )

    # -- overhead: SLO-enabled serve vs control -----------------------------
    pairs = 3 if smoke else 5
    ov = _overhead_cell(cfg, chunks, pairs)
    emit(
        "table16/overhead",
        ov["serve_eval_us"],
        f"ratio={ov['overhead_ratio']:.4f};floor={ov['overhead_floor']:.4f}",
    )
    if assert_overhead:
        estimate = min(ov["overhead_ratio"], ov["overhead_floor"])
        if estimate > OVERHEAD_BUDGET:
            raise SystemExit(
                f"SLO-enabled serve overhead {estimate:.4f} (median "
                f"{ov['overhead_ratio']:.4f}, floor {ov['overhead_floor']:.4f}) "
                f"exceeds budget {OVERHEAD_BUDGET}"
            )
        print(
            f"# overhead assertion ok: SLO-enabled ratio {estimate:.4f} "
            f"<= {OVERHEAD_BUDGET}"
        )

    # -- headroom: capacity model vs a measured pass ------------------------
    hd = _headroom_cell(cfg, chunks)
    emit(
        "table16/headroom",
        0.0,
        f"measured_fps={hd['measured_fps']:.0f};"
        f"model_fps={hd['model_fps']:.0f};"
        f"agreement={hd['headroom_agreement']:.4f}",
    )

    bench_record(
        "slo_tier",
        kind="slo",
        config={
            "G": cfg.num_groups,
            "N": cfg.frames_per_group,
            "H": cfg.height,
            "W": cfg.width,
            "backend": cfg.backend,
            "window_s": WINDOW_S,
            "miss_target": MISS_TARGET,
            "pairs": pairs,
        },
        detection_s=round(det["detection_s"], 3),
        detection_windows=round(det["detection_windows"], 4),
        eval_us=round(det["eval_us"], 1),
        recovery_s=round(kill["recovery_s"], 4),
        recovery_breached=kill["breached"],
        overhead_ratio=round(ov["overhead_ratio"], 4),
        overhead_floor=round(ov["overhead_floor"], 4),
        serve_eval_us=round(ov["serve_eval_us"], 1),
        headroom_agreement=round(hd["headroom_agreement"], 6),
    )


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="more overhead pairs")
    ap.add_argument(
        "--smoke", action="store_true", help="fewer pairs — the CI cycle"
    )
    ap.add_argument(
        "--assert-detection",
        action="store_true",
        help="exit non-zero unless the scripted overload breaches within "
        "one evaluation window and the attributed slo_breach survives "
        "the Chrome-trace export",
    )
    ap.add_argument(
        "--assert-overhead",
        action="store_true",
        help="exit non-zero unless the SLO-enabled serve paired ratio "
        f"stays <= {OVERHEAD_BUDGET}",
    )
    ap.add_argument(
        "--trace-out",
        default="table16_trace.json",
        help="where to write the detection-cell Chrome-trace artifact",
    )
    args = ap.parse_args(argv)
    run(
        quick=not args.full,
        smoke=args.smoke,
        assert_detection=args.assert_detection,
        assert_overhead=args.assert_overhead,
        trace_out=args.trace_out,
    )


if __name__ == "__main__":
    main()
