"""Paper Table 7: CPU buffered-processing baseline.

Paper compares 1..64 host threads on buffered data; this container has one
core, so we report single-thread numpy (the paper's `1 (sequential)` row)
vs the XLA-compiled path, and quote the paper's endpoints.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, emit
from repro.kernels import ops
from repro.kernels.ref import ref_numpy


def run(quick: bool = True) -> None:
    cfg = bench_config(quick)
    rng = np.random.default_rng(0)
    frames = rng.integers(
        0, 4096, (cfg.num_groups, cfg.frames_per_group, cfg.height, cfg.width)
    ).astype(np.uint16)
    n_frames = cfg.num_groups * cfg.frames_per_group

    t0 = time.perf_counter()
    ref_numpy(frames, offset=cfg.offset)
    t_np = time.perf_counter() - t0
    emit("table7/numpy_1thread", t_np * 1e6 / n_frames, f"total_s={t_np:.3f}")

    x = jnp.asarray(frames.astype(np.float32))
    f = lambda: ops.subtract_average(x, offset=cfg.offset, algorithm="alg3",
                                     backend="xla")
    jax.block_until_ready(f())
    t0 = time.perf_counter()
    jax.block_until_ready(f())
    t_xla = time.perf_counter() - t0
    emit("table7/xla_cpu", t_xla * 1e6 / n_frames, f"total_s={t_xla:.3f}")
    emit("table7/paper_cpu_1thread", 34.103e6 / 8000, "paper: 34.1s (1 bank)")
    emit("table7/paper_cpu_64thread", 1.049e6 / 8000, "paper: 1.049s (1 bank)")
