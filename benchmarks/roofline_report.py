"""Roofline report: per (arch × shape × mesh) terms from the dry-run
artifacts (§Roofline), plus the denoise kernel's own TPU roofline."""

from __future__ import annotations

import glob
import json

from benchmarks.common import emit
from repro.core import latency_model as lm


def run(quick: bool = True) -> None:
    for alg in ("alg1", "alg3"):
        r = lm.tpu_denoise_roofline_s(alg)
        emit(
            f"roofline/denoise_{alg}",
            r["memory_s"] * 1e6,
            f"bound={r['bound']};bytes={r['bytes']:.3e};flops={r['flops']:.3e}",
        )
    art = sorted(glob.glob("artifacts/dryrun/*.json"))
    if not art:
        emit("roofline/dryrun", -1, "no artifacts yet — run repro.launch.dryrun")
        return
    for path in art:
        with open(path) as f:
            rec = json.load(f)
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") != "ok":
            emit(f"roofline/{tag}", -1, rec.get("status", "?"))
            continue
        t = rec["roofline"]
        step = max(t["compute_s"], t["memory_s"], t["collective_s"])
        emit(
            f"roofline/{tag}",
            step * 1e6,
            f"dom={t['dominant']};C={t['compute_s']:.3e};M={t['memory_s']:.3e};"
            f"X={t['collective_s']:.3e};useful={rec['useful_flops_ratio']:.3f}",
        )
