"""Roofline report: per (arch × shape × mesh) terms from the dry-run
artifacts (§Roofline), the denoise kernel's own TPU roofline, and the
*achieved* fraction of that roofline for the heuristic vs the tuned tile
plan (the tuning layer's reporting hook)."""

from __future__ import annotations

import glob
import json

from benchmarks.common import bench_config, emit
from benchmarks.table12_autotune import _min_interleaved, _staged_groups
from repro.core import latency_model as lm
from repro.core.denoise import StreamingDenoiser


def _achieved_fraction(quick: bool) -> None:
    """Measured stream-step bandwidth vs the analytic HBM roofline, for
    the heuristic and the tuned plan.

    Backend is ``auto`` — ``pallas`` on TPU (where tuned geometry can
    actually differ and this is the tuning layer's headline number),
    ``xla`` elsewhere (no block geometry: both plans lower identically,
    flagged ``identical_lowering=True`` so the residual delta reads as
    host noise, not a tuning effect). Timing is table12's
    ``_min_interleaved`` — one shared alternating-paired discipline, so
    the roofline and table12 numbers stay method-comparable (sequential
    one-then-the-other timing on a loaded host reported >2x deltas
    between byte-identical programs).
    """
    n = 200 if quick else 1000
    shape = dict(num_groups=8, frames_per_group=n, height=80, width=256)
    traffic = lm.hbm_traffic_bytes("alg3", groups=8, frames_per_group=n,
                                   height=80, width=256)["streaming_total"]
    roof_s = traffic / (819.0 * 1e9)  # v5e HBM bound for the streaming path
    cfg_h = bench_config(quick, **shape, backend="auto", tile_plan="heuristic")
    cfg_t = bench_config(quick, **shape, backend="auto", tile_plan="auto")
    groups = _staged_groups(cfg_h, seed=9)
    den_h, den_t = StreamingDenoiser(cfg_h), StreamingDenoiser(cfg_t)
    identical = den_h.filter.tile_args("stream") == den_t.filter.tile_args("stream")
    heur_s, tuned_s, _ = _min_interleaved(den_h, den_t, groups, iters=4)
    for label, sec in (("heuristic", heur_s), ("tuned", tuned_s)):
        emit(
            f"roofline/achieved_{label}",
            sec * 1e6,
            f"achieved_gbps={traffic / sec / 1e9:.2f};"
            f"roofline_frac={roof_s / sec:.5f};"
            f"identical_lowering={identical}",
        )


def run(quick: bool = True) -> None:
    for alg in ("alg1", "alg3"):
        r = lm.tpu_denoise_roofline_s(alg)
        emit(
            f"roofline/denoise_{alg}",
            r["memory_s"] * 1e6,
            f"bound={r['bound']};bytes={r['bytes']:.3e};flops={r['flops']:.3e}",
        )
    _achieved_fraction(quick)
    art = sorted(glob.glob("artifacts/dryrun/*.json"))
    if not art:
        emit("roofline/dryrun", -1, "no artifacts yet — run repro.launch.dryrun")
        return
    for path in art:
        with open(path) as f:
            rec = json.load(f)
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") != "ok":
            emit(f"roofline/{tag}", -1, rec.get("status", "?"))
            continue
        t = rec["roofline"]
        step = max(t["compute_s"], t["memory_s"], t["collective_s"])
        emit(
            f"roofline/{tag}",
            step * 1e6,
            f"dom={t['dominant']};C={t['compute_s']:.3e};M={t['memory_s']:.3e};"
            f"X={t['collective_s']:.3e};useful={rec['useful_flops_ratio']:.3f}",
        )
