"""Roofline report: per (arch × shape × mesh) terms from the dry-run
artifacts (§Roofline), the denoise kernel's own TPU roofline, and the
*achieved* fraction of that roofline for the heuristic vs the tuned tile
plan (the tuning layer's reporting hook)."""

from __future__ import annotations

import glob
import json

from benchmarks.common import bench_config, emit
from benchmarks.table12_autotune import _min_interleaved, _staged_groups
from benchmarks.table13_bandwidth import _step_cost_bytes
from repro.core import latency_model as lm
from repro.core.denoise import StreamingDenoiser

#: pinned derived-field schema of the ``roofline/achieved_*`` points —
#: readers parse ``k=v`` pairs by these names, so adding/renaming a field
#: MUST go through this tuple (``tests/test_report_columns.py`` holds the
#: emitter and this schema in sync)
ACHIEVED_FIELDS = (
    "achieved_gbps",
    "roofline_frac",
    "bytes_per_frame_model",
    "bytes_per_frame_measured",
    "identical_lowering",
)


def _achieved_derived(fields: dict) -> str:
    """Render the achieved-point derived string from ``ACHIEVED_FIELDS``.

    Raises on any mismatch between the fields produced and the pinned
    schema — a silently dropped or extra field is exactly the header/row
    desync class this guards against.
    """
    if set(fields) != set(ACHIEVED_FIELDS):
        raise ValueError(
            f"achieved-point fields {sorted(fields)} do not match "
            f"ACHIEVED_FIELDS {sorted(ACHIEVED_FIELDS)}"
        )
    return ";".join(f"{k}={fields[k]}" for k in ACHIEVED_FIELDS)


def _achieved_fraction(quick: bool) -> None:
    """Measured stream-step bandwidth vs the analytic HBM roofline, for
    the heuristic and the tuned plan.

    Backend is ``auto`` — ``pallas`` on TPU (where tuned geometry can
    actually differ and this is the tuning layer's headline number),
    ``xla`` elsewhere (no block geometry: both plans lower identically,
    flagged ``identical_lowering=True`` so the residual delta reads as
    host noise, not a tuning effect). Timing is table12's
    ``_min_interleaved`` — one shared alternating-paired discipline, so
    the roofline and table12 numbers stay method-comparable (sequential
    one-then-the-other timing on a loaded host reported >2x deltas
    between byte-identical programs).
    """
    n = 200 if quick else 1000
    shape = dict(num_groups=8, frames_per_group=n, height=80, width=256)
    traffic = lm.hbm_traffic_bytes("alg3", groups=8, frames_per_group=n,
                                   height=80, width=256)["streaming_total"]
    roof_s = traffic / (819.0 * 1e9)  # v5e HBM bound for the streaming path
    cfg_h = bench_config(quick, **shape, backend="auto", tile_plan="heuristic")
    cfg_t = bench_config(quick, **shape, backend="auto", tile_plan="auto")
    groups = _staged_groups(cfg_h, seed=9)
    den_h, den_t = StreamingDenoiser(cfg_h), StreamingDenoiser(cfg_t)
    identical = den_h.filter.tile_args("stream") == den_t.filter.tile_args("stream")
    heur_s, tuned_s, _ = _min_interleaved(den_h, den_t, groups, iters=4)
    frames = 8 * n
    # bytes per frame: the analytic streaming model vs the compiler-counted
    # step (table13's measure), so every achieved point carries both sides
    # of the bandwidth ledger
    bpf_model = traffic / frames
    bpf_measured = _step_cost_bytes(cfg_h)
    for label, sec in (("heuristic", heur_s), ("tuned", tuned_s)):
        emit(
            f"roofline/achieved_{label}",
            sec * 1e6,
            _achieved_derived({
                "achieved_gbps": f"{traffic / sec / 1e9:.2f}",
                "roofline_frac": f"{roof_s / sec:.5f}",
                "bytes_per_frame_model": f"{bpf_model:.1f}",
                "bytes_per_frame_measured": f"{bpf_measured:.1f}",
                "identical_lowering": identical,
            }),
        )


def run(quick: bool = True) -> None:
    for alg in ("alg1", "alg3"):
        r = lm.tpu_denoise_roofline_s(alg)
        emit(
            f"roofline/denoise_{alg}",
            r["memory_s"] * 1e6,
            f"bound={r['bound']};bytes={r['bytes']:.3e};flops={r['flops']:.3e};"
            f"bytes_per_frame={r['bytes'] / 8000:.1f}",  # G=8, N=1000 defaults
        )
    _achieved_fraction(quick)
    art = sorted(glob.glob("artifacts/dryrun/*.json"))
    if not art:
        emit("roofline/dryrun", -1, "no artifacts yet — run repro.launch.dryrun")
        return
    for path in art:
        with open(path) as f:
            rec = json.load(f)
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") != "ok":
            emit(f"roofline/{tag}", -1, rec.get("status", "?"))
            continue
        t = rec["roofline"]
        step = max(t["compute_s"], t["memory_s"], t["collective_s"])
        emit(
            f"roofline/{tag}",
            step * 1e6,
            f"dom={t['dominant']};C={t['compute_s']:.3e};M={t['memory_s']:.3e};"
            f"X={t['collective_s']:.3e};useful={rec['useful_flops_ratio']:.3f}",
        )
