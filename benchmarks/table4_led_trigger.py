"""Paper Table 4: LED-triggered acquisition (rate-limited ingest).

The camera is throttled to the LED trigger (5 kHz -> 200 µs/frame), so a
real-time kernel is acquisition-bound: elapsed == frames x interval. We
rate-limit the synthetic source and verify Alg 3 tracks the trigger rate.
"""

from __future__ import annotations

from benchmarks.common import bench_config, emit
from repro.core.streaming import run_inline
from repro.data.prism import PrismSource


def run(quick: bool = True) -> None:
    cfg = bench_config(quick, frames_per_group=100 if quick else 200)
    groups = list(PrismSource(cfg).groups())  # pre-generate
    run_inline(cfg, iter(groups))             # warm the jit cache
    interval_us = 200.0  # 5 kHz LED trigger (paper Table 4)
    out, rep = run_inline(cfg, iter(groups), interval_us=interval_us)
    ideal = rep.frames * interval_us * 1e-6
    emit(
        "table4/led_trigger_alg3",
        rep.elapsed_s * 1e6 / rep.frames,
        f"fps={rep.fps:.0f};trigger_bound={rep.elapsed_s / ideal:.2f}x",
    )
    emit("table4/paper_fpga_alg3_led", 1.601e6 / 8000, "paper: 5000fps,205MBps")
