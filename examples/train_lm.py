"""Train a ~100M-param LM for a few hundred steps with the full driver
(checkpointing + resume included). Reduced defaults keep CPU wall time
sane; pass --steps 300 --d-model 768 for the full-size run on real HW.

  PYTHONPATH=src python examples/train_lm.py [--steps 40]
"""

import argparse

from repro.configs.base import ArchConfig
from repro.configs import registry
from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # a ~100M-class llama-style config (exact size depends on flags)
    cfg = ArchConfig(
        name="lm-100m",
        family="dense",
        num_layers=args.layers,
        d_model=args.d_model,
        num_heads=max(args.d_model // 64, 1),
        num_kv_heads=max(args.d_model // 128, 1),
        head_dim=64,
        d_ff=args.d_model * 4,
        vocab_size=2048,
        dtype="float32",
        remat=False,
    )
    # register ad hoc so the driver can resolve it
    registry._MODULES["lm-100m"] = "_adhoc"

    import sys
    import types

    m = types.ModuleType("repro.configs._adhoc")
    m.CONFIG = cfg
    m.SMOKE = cfg
    sys.modules["repro.configs._adhoc"] = m

    from repro.models import build_model

    model = build_model(cfg)
    print(f"[train_lm] params: {model.param_count():,}")
    losses = T.main([
        "--arch", "lm-100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir, "--lr", "1e-2",
    ])
    head = sum(losses[:3]) / min(3, len(losses))
    tail = sum(losses[-3:]) / min(3, len(losses))
    assert tail < head, f"loss should trend down ({head:.3f} -> {tail:.3f})"
    print(f"[train_lm] loss {head:.3f} -> {tail:.3f} OK")


if __name__ == "__main__":
    main()
