"""Pipeline-parallelism demo: 4 stages over 4 (host) devices, GPipe
schedule via shard_map + ppermute.

  PYTHONPATH=src python examples/pipeline_demo.py
(sets XLA_FLAGS itself — run as a standalone script)
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.pipeline_parallel import bubble_fraction, pipeline_forward

P_STAGES, M, MB, D = 4, 8, 4, 64
ws = jax.random.normal(jax.random.PRNGKey(0), (P_STAGES, D, D)) / jnp.sqrt(D)
xs = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

from repro.jax_compat import make_mesh

mesh = make_mesh((P_STAGES,), ("stage",))
out = pipeline_forward(
    {"w": ws}, xs, mesh, lambda p, x: jnp.tanh(x @ p["w"])
)

ref = xs
for s in range(P_STAGES):
    ref = jax.vmap(lambda x: jnp.tanh(x @ ws[s]))(ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print(f"pipeline over {P_STAGES} stages x {M} microbatches: outputs match "
      f"sequential execution")
print(f"bubble fraction: {bubble_fraction(P_STAGES, M):.3f} "
      f"(GPipe (P-1)/(P+M-1))")
