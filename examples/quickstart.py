"""Quickstart: denoise a synthetic PRISM acquisition in 20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import DenoiseConfig, StreamingDenoiser
from repro.data import PrismSource, snr_db

# One camera bank, paper geometry: 8 groups x 200 alternating frames.
cfg = DenoiseConfig(num_groups=8, frames_per_group=200, height=80, width=256)
source = PrismSource(cfg, seed=0)

den = StreamingDenoiser(cfg)
state = den.init()
for group in source.groups():          # groups stream in, camera-style
    state = den.ingest(state, group.astype(np.float32))
result = den.finalize(state)           # (N/2, H, W) averaged differences

truth = source.true_signal()
print(f"denoised {cfg.num_groups * cfg.frames_per_group} frames "
      f"-> {result.shape[0]} outputs")
print(f"output SNR: {snr_db(np.asarray(result), truth):.2f} dB")
print(f"peak signal (offset removed): "
      f"{float(np.asarray(den.remove_offset(result)).max()):.1f} ADU")
