"""Serve a small model with batched requests: prefill + decode loop.

  PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-780m]
"""

import argparse

from repro.launch import serve as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()
    S.main([
        "--arch", args.arch, "--smoke",
        "--batch", str(args.batch),
        "--prompt-len", "16",
        "--gen", str(args.gen),
    ])


if __name__ == "__main__":
    main()
