"""End-to-end PRISM pipeline: acquisition -> inline denoise -> frontend.

Demonstrates the paper's full systems argument on the framework:
  1. a rate-limited camera source (LED-trigger emulation),
  2. INLINE streaming denoise (paper Alg 3: one running sum, no staging),
  3. the same acquisition with a buffer-then-process workflow,
  4. the ring-pipelined executor (paper §5 generalized): a 3-slot ring
     plus a consumer stage downloading each partial average to host,
  5. the denoised frames feeding a modality frontend stub (patch
     embeddings for the VLM backbone) — the framework-integration path.

  PYTHONPATH=src python examples/prism_streaming.py
"""

import numpy as np

from repro.core import DenoiseConfig
from repro.core.streaming import DownloadConsumer, run_buffered, run_inline, run_pipelined
from repro.data import PrismSource, snr_db

cfg = DenoiseConfig(num_groups=8, frames_per_group=100, height=80, width=256)
interval_us = 150.0

# warm the jit caches so we measure steady-state, not compilation
groups = list(PrismSource(cfg, seed=3).groups())
run_inline(cfg, iter(groups))
run_buffered(cfg, iter(groups))

out_inline, rep_inline = run_inline(
    cfg, iter(PrismSource(cfg, seed=3).groups()), interval_us=interval_us
)
out_buffered, rep_buffered = run_buffered(
    cfg, iter(PrismSource(cfg, seed=3).groups()), interval_us=interval_us
)

print("workflow      total_s  buffering_s  compute_s   fps")
for name, r in (("inline", rep_inline), ("buffered", rep_buffered)):
    print(f"{name:<12}{r.elapsed_s:9.3f}{r.buffering_s:13.3f}"
          f"{r.compute_s:11.3f}{r.fps:9.0f}")
np.testing.assert_allclose(
    np.asarray(out_inline), np.asarray(out_buffered), rtol=1e-5
)
print("inline == buffered output: verified")

# ---- ring-pipelined: 3 overlapped stages, depth-3 ring -------------------
download = DownloadConsumer()
out_ring, rep_ring = run_pipelined(
    cfg, iter(PrismSource(cfg, seed=3).groups()), num_slots=3,
    consumer=download,
)
np.testing.assert_array_equal(np.asarray(out_inline), np.asarray(out_ring))
print(f"ring(3 slots) == inline, bit-identical; "
      f"overlap={rep_ring.overlap_frac:.0%} of staging hidden, "
      f"{len(download.partials)} partial averages downloaded")

src = PrismSource(cfg, seed=3)
print(f"SNR vs ground truth: {snr_db(np.asarray(out_inline), src.true_signal()):.2f} dB")

# ---- feed the denoised frames into a VLM frontend stub -------------------
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model

vcfg = get_config("llama-3.2-vision-11b", smoke=True)
model = build_model(vcfg)
params = model.init(jax.random.PRNGKey(0))

# patchify denoised frames -> (B, T_img, D) embeddings (frontend stub)
frames = np.asarray(out_inline)[:2]                      # 2 denoised frames
patches = frames.reshape(2, -1)[:, : vcfg.num_image_tokens * vcfg.d_model]
img = jnp.asarray(
    patches.reshape(2, vcfg.num_image_tokens, vcfg.d_model), jnp.float32
)
img = (img - img.mean()) / (img.std() + 1e-6)
tokens = jnp.ones((2, 8), jnp.int32)
logits = model.forward(params, {"tokens": tokens, "image_embeds": img})
print(f"VLM backbone consumed denoised frames: logits {logits.shape}, "
      f"finite={bool(jnp.isfinite(logits).all())}")
